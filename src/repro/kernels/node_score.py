"""Pallas TPU kernel: fused node filter+score pass for RSCH.

At high scheduling QPS on 10⁴–10⁵-node clusters, the per-cycle hot loop is
"score every candidate node" (paper §3.4 attacks exactly this cost via
search-space reduction and snapshot memory optimization).  On the TPU
adaptation we additionally *fuse* the whole filter→score pipeline into a
single VPU pass over the node table:

* the node table (free, used, mask, group_load, topo_pref) is laid out as
  flat f32/int32 vectors in HBM;
* each grid step streams one ``(8, 128)``-aligned block into VMEM via the
  BlockSpec index map, evaluates the fused predicate+polynomial, and
  writes the score block back;
* invalid nodes get ``-inf`` so downstream ``argmax`` needs no extra mask.

The node axis is padded to the block size by ``ops.py``; padding rows have
``mask = 0`` so they score ``-inf`` and can never win the argmax.

Scalar parameters (request size, strategy weights) are closed over as
Python floats — there are only a handful of strategies and pod sizes, so
the recompile space is tiny and the kernel body stays branch-free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float(jnp.finfo(jnp.float32).min)

# One VMEM tile: sublane × lane = (8, 128) for f32 — the native TPU vector
# register tiling; the node table is reshaped to (-1, LANE) rows.
SUBLANE = 8
LANE = 128
BLOCK_ROWS = 64  # rows of 128 lanes per grid step -> 8192 nodes per block


def _score_kernel(free_ref, used_ref, mask_ref, gload_ref, topo_ref,
                  out_ref, *, request: float, inv_g: float, w_used: float,
                  w_fit: float, w_group: float, w_topo: float) -> None:
    """Kernel body: one (BLOCK_ROWS, LANE) tile of the node table."""
    free = free_ref[...].astype(jnp.float32)
    used = used_ref[...].astype(jnp.float32)
    mask = mask_ref[...]
    gload = gload_ref[...]
    topo = topo_ref[...]
    valid = (mask != 0) & (free >= request)
    exact = (free == request).astype(jnp.float32)
    score = (w_used * used * inv_g + w_fit * exact
             + w_group * gload + w_topo * topo)
    out_ref[...] = jnp.where(valid, score, NEG_INF)


def _score_slots_kernel(free_ref, used_ref, mask_ref, gload_ref, topo_ref,
                        score_ref, slots_ref, *, request: float,
                        request_i: int, inv_g: float, w_used: float,
                        w_fit: float, w_group: float, w_topo: float
                        ) -> None:
    """Fused score + capacity expansion for batched gang placement.

    Alongside every node's score the kernel emits its pod-slot count
    ``floor(free / request)`` (0 where invalid), so one VPU pass over the
    node table feeds the whole-gang top-k slot selection — the per-pod
    rescoring loop disappears (§3.4).
    """
    free_i = free_ref[...]
    free = free_i.astype(jnp.float32)
    used = used_ref[...].astype(jnp.float32)
    mask = mask_ref[...]
    gload = gload_ref[...]
    topo = topo_ref[...]
    valid = (mask != 0) & (free >= request)
    exact = (free == request).astype(jnp.float32)
    score = (w_used * used * inv_g + w_fit * exact
             + w_group * gload + w_topo * topo)
    score_ref[...] = jnp.where(valid, score, NEG_INF)
    slots_ref[...] = jnp.where(valid, free_i // request_i, 0
                               ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "request", "gpus_per_node", "w_used", "w_fit", "w_group", "w_topo",
    "interpret"))
def node_scores_pallas(free: jnp.ndarray, used: jnp.ndarray,
                       mask: jnp.ndarray, group_load: jnp.ndarray,
                       topo_pref: jnp.ndarray, *, request: int,
                       gpus_per_node: int, w_used: float, w_fit: float,
                       w_group: float, w_topo: float,
                       interpret: bool = False) -> jnp.ndarray:
    """Score a 2-D node table of shape (rows, LANE).

    ``rows`` must be a multiple of ``BLOCK_ROWS``; callers go through
    :func:`repro.kernels.ops.node_scores` which pads and reshapes.
    """
    rows, lane = free.shape
    if lane != LANE:
        raise ValueError(f"lane dim must be {LANE}, got {lane}")
    if rows % BLOCK_ROWS:
        raise ValueError(f"rows ({rows}) must be a multiple of "
                         f"{BLOCK_ROWS}")
    grid = (rows // BLOCK_ROWS,)
    blk = lambda: pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))
    kernel = functools.partial(
        _score_kernel, request=float(request),
        inv_g=1.0 / float(gpus_per_node), w_used=float(w_used),
        w_fit=float(w_fit), w_group=float(w_group), w_topo=float(w_topo))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk(), blk(), blk(), blk(), blk()],
        out_specs=blk(),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(free.astype(jnp.int32), used.astype(jnp.int32),
      mask.astype(jnp.int32), group_load.astype(jnp.float32),
      topo_pref.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=(
    "request", "gpus_per_node", "w_used", "w_fit", "w_group", "w_topo",
    "interpret"))
def node_scores_slots_pallas(free: jnp.ndarray, used: jnp.ndarray,
                             mask: jnp.ndarray, group_load: jnp.ndarray,
                             topo_pref: jnp.ndarray, *, request: int,
                             gpus_per_node: int, w_used: float,
                             w_fit: float, w_group: float, w_topo: float,
                             interpret: bool = False):
    """Fused (scores, pod_slots) over a 2-D node table of shape
    (rows, LANE) — the batched gang-placement front half.  Layout
    contract matches :func:`node_scores_pallas`."""
    rows, lane = free.shape
    if lane != LANE:
        raise ValueError(f"lane dim must be {LANE}, got {lane}")
    if rows % BLOCK_ROWS:
        raise ValueError(f"rows ({rows}) must be a multiple of "
                         f"{BLOCK_ROWS}")
    grid = (rows // BLOCK_ROWS,)
    blk = lambda: pl.BlockSpec((BLOCK_ROWS, LANE), lambda i: (i, 0))
    kernel = functools.partial(
        _score_slots_kernel, request=float(request),
        request_i=int(request), inv_g=1.0 / float(gpus_per_node),
        w_used=float(w_used), w_fit=float(w_fit), w_group=float(w_group),
        w_topo=float(w_topo))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk(), blk(), blk(), blk(), blk()],
        out_specs=[blk(), blk()],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
                   jax.ShapeDtypeStruct((rows, LANE), jnp.int32)],
        interpret=interpret,
    )(free.astype(jnp.int32), used.astype(jnp.int32),
      mask.astype(jnp.int32), group_load.astype(jnp.float32),
      topo_pref.astype(jnp.float32))
