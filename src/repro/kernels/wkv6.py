"""Pallas TPU kernel: RWKV-6 (Finch) WKV recurrence with VMEM-resident
state.

The pure-jnp formulation (``rwkv6.time_mix``) scans one token at a time
and the (B, H, n, n) f32 state round-trips HBM on *every step* — ~3 state
reads/writes x 4096 steps x 32 layers dominates the rwkv6-3b x train_4k
memory roofline term (14+ s of 18 s; EXPERIMENTS.md §Perf).  On TPU the
fix is structural: keep the per-(batch, head) ``(n, n)`` state in VMEM
for the whole sequence and stream only the r/k/v/w inputs and the o
output through HBM.

Layout / grid:

* inputs r, k, v, w: ``(B, T, H, n)`` — the natural stream layout;
* grid ``(B, H, T // TB)`` with ``dimension_semantics``
  ``("parallel", "parallel", "arbitrary")`` — time is the sequential
  grid axis, so the ``(n, n)`` state lives in a VMEM scratch buffer that
  persists across the time blocks of one (b, h);
* per step (inside a block): ``o_t = r_t @ S + (r_t·u·k_t) v_t`` and
  ``S <- w_t[:, None] * S + k_t^T v_t`` — the ``u``-bonus needs no
  materialized ``kv`` outer product on the output path;
* the final state is written once per (b, h) when the last time block
  retires.

Per-(b, h) VMEM footprint: 4 stream blocks (TB, n) + state (n, n) + out
(TB, n) — ~0.4 MB at TB=256, n=64, far under the v5e VMEM budget, so the
compiler can double-buffer the streams.

HBM bytes collapse from O(T·n²) state traffic to O(T·n) streams — the
§Perf log records the analytic roofline (the CPU dry-run cannot observe
VMEM residency, so this win is reported analytically, validated by the
interpret-mode allclose tests in tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TB = 256


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                o_ref, sT_ref, state, *, tb: int, n_tblocks: int) -> None:
    """One (b, h, time-block) grid step.

    r/k/v/w_ref, o_ref: (1, TB, 1, n) VMEM blocks; u_ref: (1, n);
    s0_ref, sT_ref: (1, 1, n, n); state: (n, n) f32 VMEM scratch.
    """
    tc = pl.program_id(2)

    @pl.when(tc == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                     # (n,)

    def step(t, carry):
        r_t = r_ref[0, t, 0, :].astype(jnp.float32)      # (n,)
        k_t = k_ref[0, t, 0, :].astype(jnp.float32)
        v_t = v_ref[0, t, 0, :].astype(jnp.float32)
        w_t = w_ref[0, t, 0, :].astype(jnp.float32)
        S = state[...]                                   # (n, n)
        # o_t[m] = sum_n r[n] (S[n,m] + u[n] k[n] v[m])
        o_t = r_t @ S + jnp.sum(r_t * u * k_t) * v_t
        o_ref[0, t, 0, :] = o_t.astype(o_ref.dtype)
        state[...] = w_t[:, None] * S + k_t[:, None] * v_t[None, :]
        return carry

    jax.lax.fori_loop(0, tb, step, 0)

    @pl.when(tc == n_tblocks - 1)
    def _emit():
        sT_ref[0, 0] = state[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def wkv6_pallas(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                w: jnp.ndarray, u: jnp.ndarray, s0: jnp.ndarray,
                *, tb: int = DEFAULT_TB, interpret: bool = False):
    """RWKV-6 WKV over a full sequence.

    r, k, v, w: (B, T, H, n); u: (H, n); s0: (B, H, n, n).
    Returns (o (B, T, H, n) f32, sT (B, H, n, n) f32).
    """
    B, T, H, n = r.shape
    tb = min(tb, T)
    if T % tb:
        raise ValueError(f"T={T} not divisible by time block {tb}")
    n_tblocks = T // tb

    stream = pl.BlockSpec((1, tb, 1, n), lambda b, h, t: (b, t, h, 0))
    state_spec = pl.BlockSpec((1, 1, n, n), lambda b, h, t: (b, h, 0, 0))
    u_spec = pl.BlockSpec((1, n), lambda b, h, t: (h, 0))
    kernel = functools.partial(_wkv_kernel, tb=tb, n_tblocks=n_tblocks)

    out_shapes = (
        jax.ShapeDtypeStruct((B, T, H, n), jnp.float32),
        jax.ShapeDtypeStruct((B, H, n, n), jnp.float32),
    )
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    o, sT = pl.pallas_call(
        kernel,
        grid=(B, H, n_tblocks),
        in_specs=[stream, stream, stream, stream, u_spec, state_spec],
        out_specs=(stream, state_spec),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(r, k, v, w, u, s0)
    return o, sT
