"""Pure-jnp oracle for the fused node filter+score pass.

Semantics are identical to :func:`repro.core.scoring.node_scores_np` and
to the Pallas kernel in :mod:`repro.kernels.node_score`; all three are
asserted equal in ``tests/test_kernels.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float(jnp.finfo(jnp.float32).min)


def node_scores_ref(free: jnp.ndarray, used: jnp.ndarray,
                    mask: jnp.ndarray, group_load: jnp.ndarray,
                    topo_pref: jnp.ndarray, *, request: int,
                    gpus_per_node: int, w_used: float, w_fit: float,
                    w_group: float, w_topo: float) -> jnp.ndarray:
    """Reference: score every node, -inf where invalid.

    Args:
      free:       (n,) int — healthy free devices per node.
      used:       (n,) int — healthy allocated devices per node.
      mask:       (n,) bool/int — node is in the candidate pool.
      group_load: (n,) f32 — load fraction of the node's NodeNetGroup,
                  pre-gathered to node axis.
      topo_pref:  (n,) f32 — anchor-group preference for this job.
    """
    free_f = free.astype(jnp.float32)
    used_f = used.astype(jnp.float32)
    valid = (mask != 0) & (free_f >= float(request))
    score = (w_used * used_f / float(gpus_per_node)
             + w_fit * (free_f == float(request)).astype(jnp.float32)
             + w_group * group_load.astype(jnp.float32)
             + w_topo * topo_pref.astype(jnp.float32))
    return jnp.where(valid, score, NEG_INF).astype(jnp.float32)


def node_scores_slots_ref(free: jnp.ndarray, used: jnp.ndarray,
                          mask: jnp.ndarray, group_load: jnp.ndarray,
                          topo_pref: jnp.ndarray, *, request: int,
                          gpus_per_node: int, w_used: float, w_fit: float,
                          w_group: float, w_topo: float):
    """Oracle for the fused (scores, pod_slots) batched-gang pass."""
    scores = node_scores_ref(free, used, mask, group_load, topo_pref,
                             request=request, gpus_per_node=gpus_per_node,
                             w_used=w_used, w_fit=w_fit, w_group=w_group,
                             w_topo=w_topo)
    free_i = free.astype(jnp.int32)
    valid = (mask != 0) & (free_i >= request)
    slots = jnp.where(valid, free_i // request, 0).astype(jnp.int32)
    return scores, slots


def wkv6_ref(r, k, v, w, u, s0):
    """Pure-jnp oracle for the RWKV-6 WKV recurrence.

    r, k, v, w: (B, T, H, n); u: (H, n); s0: (B, H, n, n).
    Returns (o (B, T, H, n) f32, sT (B, H, n, n) f32) — identical math to
    ``rwkv6.time_mix``'s step scan, kept standalone so the kernel test
    does not depend on the model layer.
    """
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)
    s0 = s0.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                     # (B, H, n)
        kv = k_t[..., :, None] * v_t[..., None, :]   # (B, H, n, n)
        o = jnp.einsum("bhn,bhnm->bhm", r_t, S + u[None, :, :, None] * kv)
        return w_t[..., :, None] * S + kv, o

    tr = lambda t: t.transpose(1, 0, 2, 3)           # (T, B, H, n)
    sT, oT = jax.lax.scan(step, s0, (tr(r), tr(k), tr(v), tr(w)))
    return oT.transpose(1, 0, 2, 3), sT
