"""Public entry point for the node-scoring kernel.

``node_scores`` accepts the natural 1-D node-table layout, pads/reshapes
to the kernel's (rows, 128) tiling, dispatches to either the Pallas TPU
kernel or the pure-jnp oracle, and slices the padding back off.  Padding
rows carry ``mask = 0`` so they can never win the downstream argmax.

Backend selection:

* ``backend="pallas"``       — compiled Pallas kernel (TPU target);
* ``backend="interpret"``    — Pallas in interpret mode (CPU validation);
* ``backend="ref"``          — jnp oracle.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core.scoring import ScoreWeights
from . import node_score as _ns
from .ref import node_scores_ref

_ROW = _ns.LANE * _ns.BLOCK_ROWS


def _pad_to(x: jnp.ndarray, n: int, fill=0) -> jnp.ndarray:
    pad = n - x.shape[0]
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad,), fill, dtype=x.dtype)], axis=0)


def node_scores(free, used, mask, group_load, topo_pref, *, request: int,
                gpus_per_node: int,
                weights: Optional[ScoreWeights] = None,
                w_used: float = 0.0, w_fit: float = 0.0,
                w_group: float = 0.0, w_topo: float = 0.0,
                backend: str = "ref") -> jnp.ndarray:
    """Fused filter+score over an n-node table; returns (n,) f32 scores
    with ``-inf`` at invalid nodes."""
    if weights is not None:
        w_used, w_fit = weights.used, weights.fit
        w_group, w_topo = weights.group, weights.topo
    free = jnp.asarray(free)
    n = free.shape[0]
    kw = dict(request=request, gpus_per_node=gpus_per_node, w_used=w_used,
              w_fit=w_fit, w_group=w_group, w_topo=w_topo)

    if backend == "ref":
        return node_scores_ref(free, jnp.asarray(used), jnp.asarray(mask),
                               jnp.asarray(group_load),
                               jnp.asarray(topo_pref), **kw)
    if backend not in ("pallas", "interpret"):
        raise ValueError(f"unknown backend {backend!r}")

    padded = max(_ROW, -(-n // _ROW) * _ROW)
    rows = padded // _ns.LANE
    args2d = []
    for arr, fill in ((free, 0), (used, 0), (mask, 0),
                      (group_load, 0.0), (topo_pref, 0.0)):
        a = _pad_to(jnp.asarray(arr), padded, fill)
        args2d.append(a.reshape(rows, _ns.LANE))
    out = _ns.node_scores_pallas(
        *args2d, interpret=(backend == "interpret"), **kw)
    return out.reshape(padded)[:n]


def node_scores_and_slots(free, used, mask, group_load, topo_pref, *,
                          request: int, gpus_per_node: int,
                          weights: Optional[ScoreWeights] = None,
                          w_used: float = 0.0, w_fit: float = 0.0,
                          w_group: float = 0.0, w_topo: float = 0.0,
                          backend: str = "ref"):
    """Fused (scores, pod_slots) pass for batched gang placement.

    One sweep over the node table yields both the per-node score and the
    number of pod slots ``floor(free / request)`` each node contributes
    (0 where invalid), feeding the whole-gang top-k slot selection in
    :func:`repro.core.scoring.select_gang_slots`.
    """
    if weights is not None:
        w_used, w_fit = weights.used, weights.fit
        w_group, w_topo = weights.group, weights.topo
    free = jnp.asarray(free)
    n = free.shape[0]
    kw = dict(request=request, gpus_per_node=gpus_per_node, w_used=w_used,
              w_fit=w_fit, w_group=w_group, w_topo=w_topo)

    if backend == "ref":
        from .ref import node_scores_slots_ref
        return node_scores_slots_ref(
            free, jnp.asarray(used), jnp.asarray(mask),
            jnp.asarray(group_load), jnp.asarray(topo_pref), **kw)
    if backend not in ("pallas", "interpret"):
        raise ValueError(f"unknown backend {backend!r}")

    padded = max(_ROW, -(-n // _ROW) * _ROW)
    rows = padded // _ns.LANE
    args2d = []
    for arr, fill in ((free, 0), (used, 0), (mask, 0),
                      (group_load, 0.0), (topo_pref, 0.0)):
        a = _pad_to(jnp.asarray(arr), padded, fill)
        args2d.append(a.reshape(rows, _ns.LANE))
    scores, slots = _ns.node_scores_slots_pallas(
        *args2d, interpret=(backend == "interpret"), **kw)
    return scores.reshape(padded)[:n], slots.reshape(padded)[:n]


def gang_slot_prefilter(scores, slots, n_pods: int) -> np.ndarray:
    """Top-``n_pods`` candidate-node prefilter via ``jax.lax.top_k``.

    Set-equivalent to the numpy ``argpartition`` prefilter in
    ``repro.core.scoring``: both select, among nodes with at least one
    pod slot, the ``n_pods`` best by (slot-0 score desc, index asc) —
    ``lax.top_k`` documents lower-index-first tie-breaking, which is
    exactly the threshold-tie rule of the numpy path.  Scores at
    slotless nodes are masked to ``-inf`` before the top-k, and masked
    entries that survive an under-full top-k (fewer than ``n_pods``
    candidates exist) are filtered back out, so the returned set equals
    ``{slots > 0}`` in that case.  Returns ascending int64 node indices.
    """
    import jax

    slots = np.asarray(slots)
    cand_total = int((slots > 0).sum())
    if cand_total <= n_pods:
        return np.nonzero(slots > 0)[0]
    masked = jnp.where(jnp.asarray(slots) > 0, jnp.asarray(scores),
                       _ns.NEG_INF)
    _, idx = jax.lax.top_k(masked, n_pods)
    idx = np.asarray(idx, dtype=np.int64)
    return np.sort(idx[slots[idx] > 0])


def gang_slot_topk(free, used, mask, group_load, topo_pref, *,
                   request: int, gpus_per_node: int,
                   weights: ScoreWeights, n_pods: int,
                   fit_weight: float = 0.0, colocate_bonus: float = 0.0,
                   backend: str = "ref"):
    """Fully fused gang placement: one (scores, slots) kernel sweep, a
    ``lax.top_k`` candidate prefilter, and the shared exact-f64 chain
    epilogue from ``repro.core.scoring`` — exact-match vs the heap loop
    (the A/B oracle) whenever the slot chains are nondecreasing.

    Returns the pod→node index list, or ``None`` when the gang does not
    fit.  Raises ``ValueError`` if the weight signs violate the
    nondecreasing-chain precondition (callers should route such jobs to
    the heap engine instead).
    """
    from ..core.scoring import chains_nondecreasing, emit_slot_chains

    if not chains_nondecreasing(fit_weight, colocate_bonus):
        raise ValueError(
            "gang_slot_topk requires nondecreasing slot chains "
            "(colocate_bonus >= 0 and colocate_bonus + fit_weight >= 0)")
    scores, slots = node_scores_and_slots(
        free, used, mask, group_load, topo_pref, request=request,
        gpus_per_node=gpus_per_node, weights=weights, backend=backend)
    scores = np.asarray(scores)
    slots = np.asarray(slots)
    if int(slots.sum()) < n_pods:
        return None
    cand = gang_slot_prefilter(scores, slots, n_pods)
    return emit_slot_chains(cand, scores, np.asarray(free), slots,
                            request, n_pods, fit_weight, colocate_bonus)


def best_node(free, used, mask, group_load, topo_pref, *, request: int,
              gpus_per_node: int, weights: ScoreWeights,
              backend: str = "ref") -> int:
    """Argmax helper; returns -1 when no node is valid."""
    scores = node_scores(free, used, mask, group_load, topo_pref,
                         request=request, gpus_per_node=gpus_per_node,
                         weights=weights, backend=backend)
    idx = int(jnp.argmax(scores))
    if float(scores[idx]) <= _ns.NEG_INF:
        return -1
    return idx


def wkv6(r, k, v, w, u, s0, *, backend: str = "ref", tb: int = 256):
    """RWKV-6 WKV recurrence — kernel entry point.

    backend: "pallas" (compiled, TPU) | "interpret" (Pallas on CPU) |
    "ref" (jnp oracle).  See kernels/wkv6.py for the VMEM-residency
    argument; rwkv6.time_mix can call this in place of its step scan.
    """
    from .ref import wkv6_ref
    if backend == "ref":
        return wkv6_ref(r, k, v, w, u, s0)
    from .wkv6 import wkv6_pallas
    return wkv6_pallas(r, k, v, w, u, s0, tb=tb,
                       interpret=(backend == "interpret"))
