"""Tidal train/inference co-scheduling on one simulated day.

The cluster runs four autoscaled inference services over a deep backlog
of low-priority training.  Overnight the tide goes out — the autoscaler
retires surplus replicas and training backfills the reclaimed GPUs; at
the morning ramp new high-priority replicas preempt the backfill
through the framework's Preempt chain (PriorityPreempt) and take the
GPUs back.  A seeded node-failure injector runs throughout, so
interrupted jobs also demonstrate checkpoint-restart recovery.

Usage::

    PYTHONPATH=src python examples/tidal_cosched.py
"""

from __future__ import annotations

from repro.core import (CheckpointModel, ClusterState, DynamicsConfig,
                        NodeFailureInjector, QSCH, QSCHConfig,
                        QuotaManager, RSCH, SimConfig, Simulator,
                        TidalAutoscaler, TidalService,
                        backfill_training_trace)
from repro.core.topology import small_topology

DAY = 86_400.0


def main() -> None:
    topo = small_topology(n_nodes=64, gpus_per_node=8, nodes_per_leaf=8)
    state = ClusterState.create(topo)
    quota = QuotaManager({"svc": {0: 10**6}, "batch": {0: 10**6}})
    qsch = QSCH(quota, RSCH(topo), QSCHConfig())

    services = [TidalService(name=f"svc{i}", tenant="svc",
                             gpus_per_replica=4, min_replicas=1,
                             max_replicas=12, peak_hour=14.0)
                for i in range(4)]
    scaler = TidalAutoscaler(services, interval_s=900.0)

    backlog = backfill_training_trace(
        180, seed=0, sizes=(8, 16, 32), size_probs=(.4, .35, .25),
        duration_range_h=(2.0, 4.0))

    dynamics = DynamicsConfig(
        plugins=[scaler,
                 NodeFailureInjector(mtbf_s=24 * 3600.0, repair_s=1800.0,
                                     shape=1.2)],
        recovery=CheckpointModel(interval_s=600.0,
                                 restart_overhead_s=120.0),
        seed=0)
    sim = Simulator(state, qsch, SimConfig(horizon=2 * DAY,
                                           dynamics=dynamics))
    result = sim.run(backlog)

    print("hour  demand  infer-GPUs  train-GPUs  GAR")
    next_mark = 0.0
    for s in result.metrics.samples:
        if s.t < next_mark:     # print every ~2 simulated hours
            continue
        next_mark = s.t + 7200.0
        demand = sum(svc.target_replicas(s.t) * svc.gpus_per_replica
                     for svc in services)
        print(f"{s.t / 3600.0:5.1f}  {demand:6d}  {s.infer_allocated:10d}"
              f"  {s.train_allocated:10d}  {s.gar:.2f}")

    d = result.dynamics
    print(f"\nreplicas +{d.replicas_started}/-{d.replicas_retired} over "
          f"{result.scale_events} scale decisions; "
          f"{result.preemptions} preemptions at the ramps")
    print(f"failures {result.failures}, interrupts {result.interrupts}, "
          f"MTTR {result.metrics.mttr():.0f}s, demand satisfaction "
          f"{scaler.satisfaction():.3f}")
    assert scaler.satisfaction() > 0.9
    assert d.replicas_retired > 0 and result.preemptions > 0
    print("tidal_cosched complete")


if __name__ == "__main__":
    main()
