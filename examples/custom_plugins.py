"""Extending Kant without touching scheduler internals (framework demo).

Four extensions, each a plugin dropped into a profile — no QSCH/RSCH
changes (see ``docs/plugins.md`` for the contract):

1. **GfrAwareScore** (contrib): multi-objective fragmentation-aware
   scoring — prefer placements that *heal* fragmented nodes and avoid
   fragmenting idle ones, at node AND NodeNetGroup granularity.  Added
   to an HA-style Spread profile (spreading is inherently fragmenting)
   it cuts mean GFR (§4.3) by >30% at unchanged SOR.
2. **TenantSoftAffinity** (contrib): pull each tenant's pods toward
   NodeNetGroups the tenant already occupies.  Prints how many
   LeafGroups each tenant's pods span.
3. A ~10-line custom Score plugin written inline (the docs' worked
   example), registered and exercised through the same machinery.
4. **SemanticSoftAffinity** (contrib): generalizes (2) from tenant
   identity to token overlap over free-form ``Job.metadata`` — jobs of
   the same workload family ("llama3 finetune ...") co-locate even
   when they belong to different tenants.

Usage::

    PYTHONPATH=src python examples/custom_plugins.py
"""

from __future__ import annotations

import numpy as np

from repro.core import (ClusterState, Job, JobKind, QSCH, QuotaManager,
                        QuotaMode, RSCH, SimConfig, Simulator)
from repro.core.framework import (BackfillPolicy, GfrAwareScore,
                                  PlacementPass, ProfileSet, ScorePlugin,
                                  SemanticSoftAffinity, SpreadScore,
                                  TenantSoftAffinity, default_profiles,
                                  ebinpack_pass, make_profile, register,
                                  single_pass_plan, spread_pass)
from repro.core.topology import ClusterTopology


def topology():
    return ClusterTopology(n_nodes=64, gpus_per_node=8, nodes_per_leaf=8,
                           leaves_per_spine=4, spines_per_superspine=2,
                           nodes_per_hbd=8, nvlink_island=8, numa_split=4)


WORKLOAD_FAMILIES = ("llama3 finetune checkpointed",
                     "bert serving latency-bound",
                     "diffusion train image-batches")


def fragmenting_trace(n=260, seed=5, rate_per_hour=300.0,
                      mean_duration_s=1500.0,
                      tenants=("ads", "search", "ranker")):
    """Sub-node jobs that fragment nodes unless the scorer fights it.

    The ~60% steady-state load leaves the scheduler real placement
    freedom — a saturated cluster has none, and no Score plugin can
    change forced placements.  Each job carries a workload-family
    description in ``metadata`` that cuts ACROSS the tenant rotation,
    so semantic affinity has signal tenant affinity cannot see.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(3600.0 / rate_per_hour, size=n))
    jobs = []
    for i in range(n):
        gpus = int(rng.choice([1, 2, 3, 4, 6, 8],
                              p=[.2, .22, .13, .25, .1, .1]))
        jobs.append(Job(uid=i, tenant=tenants[i % len(tenants)],
                        gpu_type=0, n_pods=1, gpus_per_pod=gpus,
                        kind=JobKind.TRAIN,
                        submit_time=float(arrivals[i]),
                        duration=float(
                            rng.exponential(mean_duration_s) + 300.0),
                        metadata=WORKLOAD_FAMILIES[
                            (i * 7 + i // 3) % len(WORKLOAD_FAMILIES)]))
    return jobs


def run(profiles: ProfileSet, jobs):
    topo = topology()
    state = ClusterState.create(topo)
    qm = QuotaManager({t: {0: 10**6} for t in ("ads", "search", "ranker")},
                      mode=QuotaMode.SHARED)
    qsch = QSCH(qm, RSCH(topo, profiles=profiles),
                queue_policy=BackfillPolicy(head_timeout=900.0))
    sim = Simulator(state, qsch, SimConfig(tick_interval=30.0,
                                           sample_interval=120.0))
    result = sim.run([Job(uid=j.uid, tenant=j.tenant, gpu_type=j.gpu_type,
                          n_pods=j.n_pods, gpus_per_pod=j.gpus_per_pod,
                          kind=j.kind, submit_time=j.submit_time,
                          duration=j.duration, metadata=j.metadata)
                      for j in jobs])
    return topo, result


# The docs' worked example: a complete custom Score plugin in ~10
# lines.  Registered at module scope — the registry rejects duplicate
# names, so re-running main() must not re-register.
@register
class RackFirstScore(ScorePlugin):
    """Prefer low node indices ('near the rack door')."""

    name = "RackFirstScore"

    def __init__(self, weight=0.01):
        self.weight = weight

    def score(self, job, snap, pool, ctx):
        n = snap.free_gpus.shape[0]
        return self.weight * np.linspace(1.0, 0.0, n, dtype=np.float32)


def tenant_group_spans(topo, result):
    spans = {}
    for j in result.jobs:
        if j.placement is None:
            continue
        spans.setdefault(j.tenant, set()).update(
            int(topo.leaf_id[p.node]) for p in j.placement.pods)
    return {t: len(g) for t, g in sorted(spans.items())}


def family_group_spans(topo, result):
    """LeafGroups spanned per workload family (first metadata token)."""
    spans = {}
    for j in result.jobs:
        if j.placement is None or not j.metadata:
            continue
        fam = j.metadata.split()[0]
        spans.setdefault(fam, set()).update(
            int(topo.leaf_id[p.node]) for p in j.placement.pods)
    return {f: len(g) for f, g in sorted(spans.items())}


def main():
    jobs = fragmenting_trace()

    print("== 1. GFR-aware fragmentation scoring " + "=" * 26)
    topo = topology()
    default = default_profiles()

    def uniform(name, pass_):
        p = make_profile(name, single_pass_plan(pass_))
        return ProfileSet(train=p, inference=p, best_effort=p)

    # An HA-flavored cluster spreads every pod -> fragments every node.
    # The GFR objective rides along as one extra Score plugin.
    spread_only = uniform("ha-spread", spread_pass())
    spread_gfr = uniform("ha-spread-gfr", PlacementPass(
        scorers=(SpreadScore(),
                 GfrAwareScore(weight=0.5, topology=topo)),
        spread=True))
    _, base = run(spread_only, jobs)
    _, plug = run(spread_gfr, jobs)
    g0 = base.metrics.mean_gfr()
    g1 = plug.metrics.mean_gfr()
    print(f"  HA Spread           mean GFR {g0:.3f}  "
          f"SOR {base.metrics.sor():.3f}")
    print(f"  + GfrAwareScore     mean GFR {g1:.3f}  "
          f"SOR {plug.metrics.sor():.3f}")
    print(f"  fragmentation delta: {(g0 - g1) / max(g0, 1e-9) * 100:+.1f}%"
          f"  (spread HA semantics kept)")
    assert g1 < g0

    print("\n== 2. Tenant soft affinity " + "=" * 37)
    affinity = ProfileSet(
        train=make_profile("train-affinity", single_pass_plan(
            ebinpack_pass(colocate=2.0, extra_scorers=(
                TenantSoftAffinity(topo, weight=0.6, anti_weight=0.3),)))),
        inference=default.inference,
        best_effort=default.best_effort,
    )
    _, ebp = run(default_profiles(), jobs)
    _, aff = run(affinity, jobs)
    span_base = tenant_group_spans(topo, ebp)
    span_aff = tenant_group_spans(topo, aff)
    print(f"  LeafGroups spanned per tenant (E-Binpack): {span_base}")
    print(f"  LeafGroups spanned per tenant (affinity):  {span_aff}")
    assert sum(span_aff.values()) < sum(span_base.values()), \
        "soft affinity should consolidate each tenant into fewer groups"

    print("\n== 3. Write your own Score plugin (10 lines) " + "=" * 19)
    custom = ProfileSet(
        train=make_profile("train-rack-first", single_pass_plan(
            PlacementPass(scorers=(RackFirstScore(weight=5.0),)))),
        inference=make_profile("i", single_pass_plan(spread_pass())),
        best_effort=make_profile("b", single_pass_plan(spread_pass())),
    )
    state = ClusterState.create(topo)
    from repro.core.snapshot import FullSnapshotter
    rsch = RSCH(topo, profiles=custom)
    job = Job(uid=1, tenant="ads", gpu_type=0, n_pods=4, gpus_per_pod=8,
              kind=JobKind.TRAIN)
    res = rsch.schedule(job, FullSnapshotter().take(state))
    nodes = [p.node for p in res.placement.pods]
    print(f"  RackFirstScore placed the 4-pod gang on nodes {nodes}")
    assert max(nodes) <= 3

    print("\n== 4. Semantic soft affinity (job metadata) " + "=" * 20)
    # Workload families rotate out of phase with the tenant rotation:
    # tenant affinity cannot consolidate them, token overlap over
    # Job.metadata can.
    semantic = ProfileSet(
        train=make_profile("train-semantic", single_pass_plan(
            ebinpack_pass(colocate=2.0, extra_scorers=(
                SemanticSoftAffinity(topo, weight=0.8,
                                     anti_weight=0.3),)))),
        inference=default.inference,
        best_effort=default.best_effort,
    )
    _, sem = run(semantic, jobs)
    fam_base = family_group_spans(topo, ebp)
    fam_sem = family_group_spans(topo, sem)
    print(f"  LeafGroups spanned per family (E-Binpack): {fam_base}")
    print(f"  LeafGroups spanned per family (semantic):  {fam_sem}")
    assert sum(fam_sem.values()) < sum(fam_base.values()), \
        "semantic affinity should consolidate workload families"
    print("custom_plugins complete")


if __name__ == "__main__":
    main()
