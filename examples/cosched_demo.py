"""Co-scheduling demo: Kant placements -> placement-aware roofline.

The paper's JTTED metric (§4.5) argues that a placement spanning more
NodeNetGroups costs training time.  Because this framework owns both the
scheduler *and* the workloads, we close the loop (beyond-paper feature,
``repro.launch.cosched``): a Kant placement is scored by its deviation
ratios and the job's roofline collective term is rescaled by the
placement's effective bisection bandwidth.

The demo schedules the same 64-GPU training gang job twice — once with
E-Binpack (consolidates into one LeafGroup) and once with Spread (leaks
across groups) — on a pre-fragmented cluster, then prices both placements
with the dry-run roofline terms of a real (arch x shape) lowering.

Usage::

    PYTHONPATH=src python examples/cosched_demo.py
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.core import (ClusterState, Job, JobKind, RSCH, ProfileSet)
from repro.core.framework import (ebinpack_pass, make_profile,
                                  single_pass_plan, spread_pass)
from repro.core.snapshot import FullSnapshotter
from repro.core.topology import ClusterTopology
from repro.launch.cosched import (estimated_step_time, job_mesh_shape,
                                  placement_quality)

DRYRUN_GLOB = "experiments/dryrun/glm4-9b__train_4k__16x16__*.json"
FALLBACK_TERMS = {"compute": 3.0e-1, "memory": 9.0e-1,
                  "collective": 2.0e-1}     # glm4-9b/train_4k magnitudes


def load_terms():
    hits = sorted(glob.glob(DRYRUN_GLOB))
    if not hits:
        print(f"  (no dry-run artifact under {os.path.dirname(DRYRUN_GLOB)}"
              " — using fallback terms; run `python -m repro.launch.dryrun"
              " --arch glm4-9b --shape train_4k` for real numbers)")
        return FALLBACK_TERMS, "fallback"
    with open(hits[0]) as f:
        r = json.load(f)
    return ({"compute": r["compute_term_s"], "memory": r["memory_term_s"],
             "collective": r["collective_term_s"]}, os.path.basename(hits[0]))


def uniform_profiles(name: str, pass_) -> ProfileSet:
    """One placement pass for every workload class (framework API)."""
    p = make_profile(name, single_pass_plan(pass_))
    return ProfileSet(train=p, inference=p, best_effort=p)


SPREAD_PROFILES = uniform_profiles("bg-spread", spread_pass())
EBINPACK_PROFILES = uniform_profiles("bg-e-binpack",
                                     ebinpack_pass(colocate=2.0))


def fragment(state: ClusterState, topo: ClusterTopology,
             rng: np.random.Generator, profiles: ProfileSet,
             n_jobs: int = 48) -> None:
    """Place small background jobs with the profile under test.

    Spread scatters them across every LeafGroup; E-Binpack consolidates
    them into few groups, *reserving whole groups* for the large job that
    arrives next (§3.3.3 LeafGroup-level E-Binpack)."""
    rsch = RSCH(topo, profiles=profiles)
    for uid in range(10_000, 10_000 + n_jobs):
        j = Job(uid=uid, tenant="bg", gpu_type=0, n_pods=1,
                gpus_per_pod=int(rng.choice([2, 4])), kind=JobKind.TRAIN,
                gang=True, submit_time=0.0, duration=1e9)
        res = rsch.schedule(j, FullSnapshotter().take(state))
        if res.placement is not None:
            state.allocate(j, res.placement)


def place_and_price(bg_name: str, bg_profiles: ProfileSet, topo, terms,
                    seed: int = 3):
    """Fill the cluster with small jobs under ``bg_profiles``, then place
    one 64-GPU gang training job and price its placement."""
    state = ClusterState.create(topo)
    fragment(state, topo, np.random.default_rng(seed), bg_profiles)
    job = Job(uid=1, tenant="llm", gpu_type=0, n_pods=8, gpus_per_pod=8,
              kind=JobKind.TRAIN, gang=True, submit_time=0.0,
              duration=3600.0)
    rsch = RSCH(topo, profiles=EBINPACK_PROFILES)
    res = rsch.schedule(job, FullSnapshotter().take(state))
    if res.placement is None:
        print(f"  bg={bg_name:10s}: 64-GPU job does not fit "
              f"({res.reason})")
        return None
    q = placement_quality(res.placement, topo, job.n_gpus)
    t = estimated_step_time(terms, q)
    from repro.launch.cosched import effective_collective_bw
    from repro.launch.mesh import ICI_BW
    coll = terms["collective"] * ICI_BW / effective_collective_bw(q)
    print(f"  bg={bg_name:10s}: nodes={q.n_nodes} "
          f"groups={q.n_groups} node_dev={q.node_dev:.2f} "
          f"group_dev={q.group_dev:.2f} "
          f"cross_group={q.cross_group_fraction:.2f} "
          f"-> collective {coll:.2f}s, est step {t*1e3:.0f} ms")
    return t, coll


def main():
    terms, src = load_terms()
    print(f"roofline terms from {src}:")
    print(f"  compute {terms['compute']:.3e}s  memory "
          f"{terms['memory']:.3e}s  collective {terms['collective']:.3e}s")
    data, model = job_mesh_shape(64)
    print(f"64-GPU job mesh factorization: data={data} x model={model}\n")

    topo = ClusterTopology(n_nodes=64, gpus_per_node=8, nodes_per_leaf=8,
                           leaves_per_spine=4, spines_per_superspine=2,
                           nodes_per_hbd=8, nvlink_island=8, numa_split=4)
    print("one 64-GPU (8 pods x 8) gang training job arriving on a "
          "512-GPU cluster\nalready running 48 small jobs placed with the "
          "strategy under test:")
    r_spread = place_and_price("SPREAD", SPREAD_PROFILES, topo, terms)
    r_ebp = place_and_price("E_BINPACK", EBINPACK_PROFILES, topo, terms)

    if r_spread and r_ebp:
        (t_s, c_s), (t_e, c_e) = r_spread, r_ebp
        print(f"\nE-Binpack background packing cuts the large job's "
              f"collective term {c_s / c_e:.2f}x "
              f"({c_s:.2f}s -> {c_e:.2f}s); step estimate "
              f"{t_s*1e3:.0f} -> {t_e*1e3:.0f} ms "
              f"(memory-bound here, so the win shows once the memory "
              f"term is optimized — see EXPERIMENTS.md §Perf)")
        assert c_e <= c_s + 1e-12
        assert t_e <= t_s + 1e-12
    print("cosched_demo complete")


if __name__ == "__main__":
    main()
