"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on synthetic data, with checkpointing and resume.

This is the deliverable-(b) end-to-end example: the full substrate path —
config -> model init -> data pipeline -> jitted train_step (loss + AdamW)
-> checkpoint save/restore — exactly the code the production launcher
lowers under the 256-chip mesh (see ``repro.launch.dryrun``), here run on
CPU at a ~100M scale.

``--resume`` restarts from the last checkpoint in ``--ckpt``: the
model-level half of the checkpoint-restart story the scheduler-level
dynamics subsystem models (``repro.core.dynamics.recovery`` — a killed
job re-enters the queue with ``original - checkpointed + overhead``
seconds of work; this driver is where those checkpoints come from).

Usage::

    PYTHONPATH=src python examples/train_e2e.py                 # 300 steps
    PYTHONPATH=src python examples/train_e2e.py --steps 20      # quick look
    PYTHONPATH=src python examples/train_e2e.py --resume        # restart
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.ckpt import load_checkpoint, save_checkpoint
from repro.configs.base import ArchConfig
from repro.data import DataConfig, synthetic_batches
from repro.train import AdamWConfig, TrainState

# ~99M parameters: 2*V*d embed/head (8.4M) + 22 blocks of
# (4d^2 attn + 3*d*d_ff SwiGLU) ~ 90M.  vocab 8192 keeps the synthetic
# bigram task learnable within a few hundred steps.
ARCH_100M = ArchConfig(
    name="repro-100m", family="dense", n_layers=22, d_model=512,
    n_heads=8, n_kv_heads=4, d_ff=2048, vocab=8192, rope_theta=1e4,
    citation="(ours) ~100M e2e example")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="continue from the last checkpoint in --ckpt "
                         "(simulated failure recovery)")
    args = ap.parse_args()

    cfg = ARCH_100M
    n = cfg.n_params()
    print(f"arch {cfg.name}: {n/1e6:.1f}M params, "
          f"{cfg.n_layers}L d={cfg.d_model} ff={cfg.d_ff} v={cfg.vocab}")

    state = TrainState(cfg, jax.random.PRNGKey(args.seed),
                       AdamWConfig(lr=args.lr, weight_decay=0.01))
    data = synthetic_batches(cfg, DataConfig(batch=args.batch,
                                             seq=args.seq, seed=args.seed))
    start = 0
    manifest = os.path.join(args.ckpt, "manifest.json")
    if args.resume and os.path.exists(manifest):
        restored = load_checkpoint(args.ckpt)
        state.params = restored["params"]
        state.opt_state = restored["opt"]
        start = int(restored["step"])
        # Replay the data stream to where the checkpoint left off, so a
        # resumed run sees the batches the killed run never trained on.
        for _ in range(start):
            next(data)
        print(f"resumed from {args.ckpt} @ step {start} "
              f"(recomputing nothing, restart overhead only)")
    elif args.resume:
        print(f"no checkpoint under {args.ckpt}; starting from scratch")

    tokens_per_step = args.batch * args.seq
    t0 = time.time()
    for i in range(start, args.steps):
        m = state.step(next(data))
        if i % 10 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tps = tokens_per_step * (i + 1) / max(dt, 1e-9)
            print(f"step {i:4d}  loss {m['loss']:.4f}  "
                  f"gnorm {m['grad_norm']:.3f}  {tps:7.0f} tok/s "
                  f"({dt:.0f}s)", flush=True)
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, {"params": state.params,
                                        "opt": state.opt_state}, step=i + 1)
            print(f"  checkpoint @ step {i+1} -> {args.ckpt}")

    losses = [h["loss"] for h in state.history]
    k = max(1, len(losses) // 5)
    first = sum(losses[:k]) / k
    last = sum(losses[-k:]) / k
    print(f"\nmean loss first-{k} {first:.4f} -> last-{k} {last:.4f}")
    if args.steps - start >= 50:  # too noisy to assert on a quick look
        assert last < first, "training must reduce the loss"

    if args.ckpt and args.steps >= args.ckpt_every:
        restored = load_checkpoint(args.ckpt)
        leaves = jax.tree_util.tree_leaves(restored["params"])
        print(f"restore check: step={restored['step']}, "
              f"{len(leaves)} param leaves, "
              f"dtype {leaves[0].dtype}  [ok]")
    print("train_e2e complete")


if __name__ == "__main__":
    main()
