"""Multi-tenant inference cluster (paper §5.2) + real serving path.

Part 1 reproduces the §5.2 scenario shape: a sub-thousand-GPU
heterogeneous cluster (two GPU types), three tenants with per-type
quotas, an E-Spread inference dedicated zone, and a mixed fleet of
small HA inference services plus a few multi-node distributed-inference
jobs.  It prints GAR / SOR / GFR and the per-tenant quota picture.

Part 2 actually *serves* one of those placed services: the ServeEngine
runs continuous batching (prefill + decode with a KV cache) over a
reduced glm4-9b, the same decode_step the dry-run lowers at
decode_32k/long_500k scale.

Part 2 deliberately stays on the *legacy whole-batch shim*
(``per_slot=False``): every request shares one token budget, so slots
turn over in lock-step waves and the legacy re-prefill only ever covers
freshly admitted prompts — here the shim is as cheap as per-slot admit
and pins the original engine semantics as an executable regression
reference.  The per-slot path, and the workloads where it actually wins
(staggered budgets, requests finishing mid-flight), are exercised by
``benchmarks/serving_bench.py`` and documented in docs/serving.md.

Usage::

    PYTHONPATH=src python examples/inference_cluster.py
"""

from __future__ import annotations

import numpy as np

import jax

from repro.core import (ClusterState, Job, JobKind, QSCH, QSCHConfig,
                        QueuePolicy, QuotaManager, QuotaMode, RSCH,
                        RSCHConfig, SimConfig, Simulator, Strategy)
from repro.core.topology import ClusterTopology


def build_jobs(rng: np.random.Generator, n_small: int = 60,
               n_large: int = 4):
    """Small HA replica services + DeepSeek-V3-style multi-node EP jobs."""
    jobs, uid = [], 0
    tenants = ["search", "chat", "api"]
    for i in range(n_small):
        gpus = int(rng.choice([1, 2, 4], p=[0.5, 0.3, 0.2]))
        replicas = int(rng.integers(2, 5))
        for _ in range(replicas):
            jobs.append(Job(
                uid=uid, tenant=tenants[i % 3],
                gpu_type=int(rng.random() < 0.3),
                n_pods=1, gpus_per_pod=gpus, kind=JobKind.INFER,
                gang=False, submit_time=float(rng.uniform(0, 1800)),
                duration=float(rng.uniform(3600, 7200))))
            uid += 1
    for _ in range(n_large):       # 8-node x 8-GPU EP inference (gang)
        jobs.append(Job(uid=uid, tenant="chat", gpu_type=0, n_pods=8,
                        gpus_per_pod=8, kind=JobKind.INFER, gang=True,
                        submit_time=float(rng.uniform(600, 2400)),
                        duration=7200.0))
        uid += 1
    jobs.sort(key=lambda j: j.submit_time)
    return jobs


def main():
    print("== Part 1: Kant on a heterogeneous inference cluster ==")
    # 96 nodes x 8 GPUs = 768 GPUs; nodes 64.. are GPU type 1 ("Type-A"),
    # the rest type 0 ("Type-L").  16 nodes form the E-Spread zone.
    topo = ClusterTopology(n_nodes=96, gpus_per_node=8, nodes_per_leaf=8,
                           leaves_per_spine=4, spines_per_superspine=3,
                           nodes_per_hbd=8, nvlink_island=8, numa_split=4)
    gpu_types = np.zeros(96, np.int32)
    gpu_types[64:] = 1
    state = ClusterState.create(topo, gpu_type=gpu_types,
                                inference_zone_nodes=16)
    quota = {"search": {0: 160, 1: 64}, "chat": {0: 256, 1: 96},
             "api": {0: 96, 1: 96}}
    qm = QuotaManager(quota, mode=QuotaMode.SHARED)
    rsch = RSCH(topo, RSCHConfig(train_strategy=Strategy.E_BINPACK,
                                 infer_strategy=Strategy.E_SPREAD))
    qsch = QSCH(qm, rsch, QSCHConfig(policy=QueuePolicy.BACKFILL))
    sim = Simulator(state, qsch, SimConfig(tick_interval=15.0,
                                           sample_interval=120.0,
                                           horizon=3600.0))
    rng = np.random.default_rng(11)
    result = sim.run(build_jobs(rng))
    rep = result.metrics.report()
    print(f"  GAR(median)={rep['median_gar']:.3f}  SOR={rep['sor']:.3f}  "
          f"GFR(mean)={rep['mean_gfr']:.3f}")
    placed = [j for j in result.jobs if j.placement is not None]
    by_tenant = {}
    for j in placed:
        by_tenant.setdefault(j.tenant, [0, 0])
        by_tenant[j.tenant][j.gpu_type] += j.n_gpus
    for t, (l_gpus, a_gpus) in sorted(by_tenant.items()):
        q = quota[t]
        print(f"  tenant {t:7s} used Type-L {l_gpus:4d}/{q[0]:4d}  "
              f"Type-A {a_gpus:3d}/{q[1]:3d}")
    zone_jobs = sum(1 for j in placed if not j.gang and j.placement and
                    all(p.node < 16 for p in j.placement.pods))
    print(f"  small inference pods fully inside the E-Spread zone: "
          f"{zone_jobs}")

    print("\n== Part 2: serve a placed model (continuous batching) ==")
    from repro.launch.serve import serve_demo
    # Legacy shim on purpose — see the module docstring for why.
    finished = serve_demo("glm4-9b", requests=10, batch_size=4, max_new=6,
                          per_slot=False)
    assert len(finished) == 10
    print("inference_cluster complete")


if __name__ == "__main__":
    main()
