"""Quickstart: the Kant scheduling loop + the workloads it schedules.

Runs in ~30 s on CPU and tours the public API end to end:

1. build a 256-GPU cluster (leaf/spine topology, 8-GPU nodes);
2. assemble scheduling profiles from the plugin framework
   (``repro.core.framework``, see docs/plugins.md) — Kant's defaults
   (Backfill + E-Binpack) vs a Strict-FIFO/plain-Binpack baseline;
3. schedule a mixed training trace with both and print the paper's five
   metrics (GAR, SOR, GFR, JWTD, JTTED);
4. run a few training steps of a reduced ("smoke") model — the same model
   zoo the production dry-run lowers onto the 256/512-chip meshes.

Usage::

    PYTHONPATH=src python examples/quickstart.py
"""

from __future__ import annotations

import jax

from repro.core import (ClusterState, QSCH, QuotaManager, QuotaMode, RSCH,
                        SimConfig, Simulator, training_trace)
from repro.core.framework import (BackfillPolicy, ProfileSet,
                                  StrictFIFOPolicy, binpack_pass,
                                  default_profiles, make_profile,
                                  single_pass_plan)
from repro.core.topology import ClusterTopology

# The baseline scheduler as explicit profiles: plain node-level Binpack
# for every workload class, Strict-FIFO queue.  Kant's defaults come
# from default_profiles(): E-Binpack training, E-Spread inference.
BASELINE_PROFILES = ProfileSet(
    train=make_profile("train-binpack", single_pass_plan(binpack_pass())),
    inference=make_profile("infer-binpack",
                           single_pass_plan(binpack_pass())),
    best_effort=make_profile("dev-binpack",
                             single_pass_plan(binpack_pass())),
)


def schedule(queue_policy, profiles: ProfileSet, jobs):
    topo = ClusterTopology(n_nodes=32, gpus_per_node=8, nodes_per_leaf=8,
                           leaves_per_spine=2, spines_per_superspine=2,
                           nodes_per_hbd=8, nvlink_island=8, numa_split=4)
    state = ClusterState.create(topo)
    qm = QuotaManager({"team-a": {0: 10**6}}, mode=QuotaMode.SHARED)
    rsch = RSCH(topo, profiles=profiles)
    qsch = QSCH(qm, rsch, queue_policy=queue_policy)
    sim = Simulator(state, qsch, SimConfig(tick_interval=30.0,
                                           sample_interval=120.0))
    return sim.run(jobs)


def show(tag, result):
    rep = result.metrics.report()
    print(f"  {tag:28s} GAR(med)={rep['median_gar']:.3f} "
          f"SOR={rep['sor']:.3f} GFR(mean)={rep['mean_gfr']:.3f} "
          f"preemptions={result.preemptions}")
    return rep


def main():
    print("== 1. Kant vs baseline on a 256-GPU cluster " + "=" * 20)
    jobs = [j for j in training_trace(150, seed=7,
                                      arrival_rate_per_hour=500.0,
                                      mean_duration_s=1800.0)
            if j.n_gpus <= 64]
    base = schedule(StrictFIFOPolicy(), BASELINE_PROFILES, list(jobs))
    kant = schedule(BackfillPolicy(head_timeout=600.0),
                    default_profiles(), list(jobs))
    show("Strict FIFO + Binpack", base)
    rep = show("Kant (Backfill + E-Binpack)", kant)
    if rep["jtted"]:
        print("  JTTED (node_dev, group_dev) by job size:",
              {k: (round(a, 2), round(b, 2))
               for k, (a, b) in rep["jtted"].items()})

    print("\n== 2. Train a smoke model (the scheduled workload) " + "=" * 12)
    from repro.configs import make_inputs
    from repro.launch.train import train_loop
    state = train_loop("glm4-9b", smoke=True, steps=6, batch=4, seq=32,
                       log_every=2)
    losses = [h["loss"] for h in state.history]
    assert losses[-1] < losses[0], "loss should go down"
    print(f"  loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps  [ok]")

    print("\n== 3. One forward pass per family " + "=" * 29)
    from repro.configs import get_arch
    from repro.models.model import Model
    for arch in ("mixtral-8x7b", "rwkv6-3b", "hymba-1.5b",
                 "llava-next-34b"):
        cfg = get_arch(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_inputs(cfg, batch=2, seq=16, kind="train")
        logits, _aux = model.forward(params, batch)
        print(f"  {arch:28s} [{cfg.family:6s}] logits {logits.shape}  ok")
    print("\nquickstart complete")


if __name__ == "__main__":
    main()
