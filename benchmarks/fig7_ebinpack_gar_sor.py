"""Fig 7: GAR and SOR with E-Binpack vs native (§5.1.3).

Paper: median gains ~+4.6% GAR and ~+4.1% SOR — consolidation keeps
whole nodes free so large jobs are admitted instead of blocking."""

from repro.core import Strategy

from .common import (fragmenting_jobs, loaded_horizon, print_metrics,
                     run_scenario, scaled_training_jobs)


def main() -> dict:
    # Mixed workload: fragmenting small jobs + multi-node gangs.
    jobs = fragmenting_jobs(350, seed=7) + [
        j for j in scaled_training_jobs(150, seed=8) if j.n_gpus >= 32]
    for i, j in enumerate(jobs):
        j.uid = i
    h = loaded_horizon(jobs)
    spread = run_scenario(jobs, train_strategy=Strategy.SPREAD, horizon=h)
    ebp = run_scenario(jobs, train_strategy=Strategy.E_BINPACK, horizon=h)
    rs = print_metrics("native (spread)", spread)
    rb = print_metrics("E-Binpack", ebp)
    print(f"deltas: GAR {rb['median_gar'] - rs['median_gar']:+.3f}  "
          f"SOR {rb['sor'] - rs['sor']:+.3f}")
    assert rb["sor"] >= rs["sor"] - 1e-9
    return {"gar": (rs["median_gar"], rb["median_gar"]),
            "sor": (rs["sor"], rb["sor"])}


if __name__ == "__main__":
    main()
