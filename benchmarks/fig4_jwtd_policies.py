"""Fig 4 + Table 1: JWTD under Backfill / Strict FIFO / Best-Effort FIFO.

Paper: Backfill keeps JWTD stable; Best-Effort starves the largest jobs
(1024/2048-GPU waits blow up) because nothing ever preempts for them."""

import numpy as np

from repro.core import QueuePolicy

from .common import print_metrics, run_scenario, scaled_training_jobs


def _wait_of_biggest(result, jobs):
    big = max(j.n_gpus for j in result.jobs)
    waits = [j.waiting_time for j in result.jobs
             if j.n_gpus == big and j.waiting_time is not None]
    return big, float(np.mean(waits)) if waits else float("inf")


def main() -> dict:
    jobs = scaled_training_jobs(500, seed=4)
    out = {}
    results = {}
    for policy in (QueuePolicy.STRICT_FIFO, QueuePolicy.BEST_EFFORT_FIFO,
                   QueuePolicy.BACKFILL):
        res = run_scenario(jobs, policy=policy,
                           backfill_head_timeout=600.0)
        rep = print_metrics(policy.value, res)
        big, wait = _wait_of_biggest(res, jobs)
        print(f"    mean wait of {big}-GPU jobs: {wait:.0f}s")
        out[policy.value] = wait
        results[policy] = rep
    # Best-Effort starves the biggest jobs relative to Backfill (Fig 4).
    assert out["best-effort-fifo"] >= out["backfill"], out
    return out


if __name__ == "__main__":
    main()
