"""Federation benchmark: parity, spillover vs static partitioning,
GSCH routing overhead.

Three gates, one per acceptance criterion of the federation subsystem:

1. **Parity** — a FederatedSimulator with ONE member reproduces the
   plain Simulator byte-identically (placements, metric reports AND the
   raw sample series) across a policy × strategy matrix.
2. **Spillover** — on a 3-member heterogeneous federation (mixed node
   counts, ``gpus_per_node`` and GPU-type pools) with regionally skewed
   demand, deadline-based spillover re-routing beats static per-cluster
   partitioning on P90 JWTD at equal-or-better global GAR, and raises
   the cross-cluster balance index.  Both runs start from the *same*
   static routing (a ClusterSelect plugin pinning each job to its
   type-aware home member), so the delta is attributable to spillover
   alone.
3. **Overhead** — the federated lockstep loop + GSCH summary/routing
   machinery costs <= 10 % per cycle versus the sum of the same members
   run standalone (3 x 10k-node members full-size; scaled down under
   ``--smoke``).  Routing itself is O(members) per job: the summary
   matrix is rebuilt at most once per staleness window (asserted on the
   refresh counter).

Writes ``BENCH_federation.json`` (uploaded as a CI artifact).
"""

from __future__ import annotations

import argparse
import math
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/federation_bench.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import bench_seed, clone_jobs, \
    write_bench_json  # noqa: E402
from repro.core import (FederatedCluster, FederatedSimulator, GSCHConfig,
                        Job, QueuePolicy, Simulator, Strategy,
                        make_member, training_trace)  # noqa: E402
from repro.core.framework import ClusterSelectPlugin  # noqa: E402
from repro.core.federation import (allocated_gar, QuotaFitSelect,
                                   waiting_percentile)  # noqa: E402

TENANT_REGIONS = {"tA": "r0", "tB": "r0", "tC": "r1", "tD": "r2"}
TENANTS = tuple(TENANT_REGIONS)


# ----------------------------------------------------------------------
# 1. Parity: one member == plain Simulator, byte-identical
# ----------------------------------------------------------------------
def placement_fingerprint(jobs: Sequence[Job]) -> List:
    return [(j.uid, j.start_time, j.end_time,
             tuple((p.node, p.gpu_indices)
                   for p in (j.placement.pods if j.placement else ())))
            for j in jobs]


def sample_fingerprint(metrics) -> List:
    return [(s.t, s.gar, s.gfr, s.allocated, s.capacity, s.queue_depth)
            for s in metrics.samples]


def parity_gate(seed: int, smoke: bool) -> Dict:
    jobs = training_trace(120 if smoke else 240, seed=seed,
                          arrival_rate_per_hour=500,
                          mean_duration_s=2400.0)
    jobs = [j for j in jobs if j.n_gpus <= 128]
    configs = [(p, s)
               for p in (QueuePolicy.BACKFILL, QueuePolicy.STRICT_FIFO,
                         QueuePolicy.BEST_EFFORT_FIFO)
               for s in (Strategy.E_BINPACK, Strategy.BINPACK)]
    checked = 0
    for policy, strategy in configs:
        def member():
            return make_member("solo", gpu_pools=((0, 64),),
                               policy=policy, strategy=strategy)
        m = member()
        base = Simulator(m.state, m.qsch, m.sim_config).run(
            clone_jobs(jobs))
        fed = FederatedSimulator(FederatedCluster([member()])).run(
            clone_jobs(jobs))
        mres = fed.members[0]
        assert placement_fingerprint(base.jobs) \
            == placement_fingerprint(mres.jobs), \
            f"placement parity broken: {policy} x {strategy}"
        assert sample_fingerprint(base.metrics) \
            == sample_fingerprint(mres.metrics), \
            f"sample parity broken: {policy} x {strategy}"
        assert base.metrics.report() == mres.metrics.report(), \
            f"metric parity broken: {policy} x {strategy}"
        checked += 1
    print(f"--- parity: single-member FederatedSimulator byte-identical "
          f"to Simulator across {checked} policy x strategy configs")
    return {"configs_checked": checked}


# ----------------------------------------------------------------------
# 2. Spillover vs static per-cluster partitioning
# ----------------------------------------------------------------------
def hetero_members(scale: int = 1) -> FederatedCluster:
    """Mixed node counts, gpus_per_node AND GPU-type pools."""
    return FederatedCluster([
        make_member("east-h100", region="r0", tenants=TENANTS,
                    gpu_pools=((0, 40 * scale),), gpus_per_node=8),
        make_member("west-h100", region="r1", tenants=TENANTS,
                    gpu_pools=((0, 16 * scale), (1, 16 * scale)),
                    gpus_per_node=8),
        make_member("west-a100", region="r2", tenants=TENANTS,
                    gpu_pools=((1, 48 * scale),), gpus_per_node=4),
    ])


class StaticPartitionSelect(ClusterSelectPlugin):
    """Type-aware static partitioning as a ClusterSelect plugin: each
    job is pinned to its home-region member, falling back to the first
    member hosting its GPU type.  The baseline the spillover run starts
    from — and the whole policy of the no-spill run."""

    name = "StaticPartitionSelect"

    def __init__(self, fed: FederatedCluster) -> None:
        self.regions = [m.region for m in fed.members]

    def assign(self, job: Job, summary) -> int:
        fits = summary.structural_fit(job)
        home = (self.regions.index(job.region)
                if job.region in self.regions else 0)
        if fits[home]:
            return home
        order = np.nonzero(fits)[0]
        if len(order):
            return int(order[0])
        c = summary.col(job.gpu_type)
        if c is None:
            return home
        return int(np.argmax(summary.capacity[:, c]))

    def score(self, job: Job, summary) -> np.ndarray:
        out = np.zeros(summary.n_members)
        out[self.assign(job, summary)] = 1e6
        return out


def skewed_workload(seed: int, smoke: bool, scale: int = 1) -> List[Job]:
    """Regionally skewed demand: r0 tenants oversubscribe the east
    member during a burst while west members keep headroom."""
    n = (300 if smoke else 420) * scale
    jobs = training_trace(
        n, seed=seed, arrival_rate_per_hour=(700.0 if smoke else 900.0)
        * scale,
        mean_duration_s=4200.0, tenants=TENANTS,
        tenant_regions=TENANT_REGIONS,
        gpu_types=(0, 1), type_probs=(0.65, 0.35))
    return [j for j in jobs if j.n_gpus <= 64 * scale]


def spillover_gate(seed: int, smoke: bool) -> Dict:
    jobs = skewed_workload(seed, smoke)
    horizon = 10 * 3600.0

    def run(spillover: bool):
        fed = hetero_members()
        cfg = GSCHConfig(
            select=(QuotaFitSelect(), StaticPartitionSelect(fed)),
            immediate_fit_bonus=0.0,
            spillover=spillover,
            spill_deadline_s=600.0, forward_delay_s=60.0,
            locality_penalty_s=240.0)
        sim = FederatedSimulator(fed, cfg, horizon=horizon)
        return sim.run(clone_jobs(jobs))

    static = run(spillover=False)
    spill = run(spillover=True)
    # GAR/balance over the backlog window [0, T]: T = the last job
    # START across both runs.  Up to T at least one run still has
    # queued work, so time-averaged GAR measures how well each router
    # used the loaded period; past T it is pure drain tail, which would
    # penalize the router that finished the same work earlier.
    T = max(j.start_time for res in (static, spill) for j in res.jobs
            if j.start_time is not None)
    capacity = sum(m.state.total_allocatable()
                   for m in hetero_members().members)
    stats = {}
    for tag, res in (("static", static), ("spillover", spill)):
        stats[tag] = {
            "p90_jwtd_s": waiting_percentile(res.jobs, 90.0),
            # Exact interval-based window GAR: the sampled estimate's
            # step-hold bias exceeds the effect under test at this
            # cluster size.
            "mean_gar_loaded": allocated_gar(res.jobs, capacity, T,
                                             default_end=horizon),
            "sor": res.metrics.sor(),
            "balance_loaded": res.metrics.balance_index(T),
        }
    stats["spillover"].update(
        spills=spill.spills,
        cross_region=spill.routing.cross_region_forwards)
    print("--- spillover vs static partitioning "
          f"(3 heterogeneous members, {len(jobs)} jobs, "
          f"window {T / 3600:.1f}h)")
    for tag in ("static", "spillover"):
        s = stats[tag]
        print(f"    {tag:9s}: P90 JWTD {s['p90_jwtd_s']:7.0f}s   "
              f"loaded GAR {s['mean_gar_loaded']:.3f}   "
              f"SOR {s['sor']:.3f}   balance {s['balance_loaded']:.3f}")
    print(f"    {spill.spills} spills, "
          f"{spill.routing.cross_region_forwards} cross-region forwards")
    assert spill.spills > 0, "scenario must actually exercise spillover"
    # waiting_percentile returns NaN on "no started jobs" — that is
    # missing data, not a 0 s tail; the gate requires real waits.
    assert not any(math.isnan(stats[tag]["p90_jwtd_s"])
                   for tag in ("static", "spillover")), \
        "no waiting-time data in the spillover scenario"
    assert stats["spillover"]["p90_jwtd_s"] \
        < stats["static"]["p90_jwtd_s"], \
        "spillover must beat static partitioning on P90 JWTD"
    # "Equal-or-better": spilled jobs spend forward_delay (+ locality
    # penalty) allocated nowhere, a real modeled cost that shows up as
    # sub-1% window-GAR wobble when the congested window is short.
    # 0.5% relative tolerance = "equal"; real regressions measured 5%+.
    assert stats["spillover"]["mean_gar_loaded"] \
        >= 0.995 * stats["static"]["mean_gar_loaded"], \
        "spillover must not lose loaded-window global GAR"
    assert stats["spillover"]["balance_loaded"] \
        >= stats["static"]["balance_loaded"], \
        "spillover should improve cross-cluster balance"
    return stats


# ----------------------------------------------------------------------
# 3. Per-cycle overhead vs standalone members (O(members) routing)
# ----------------------------------------------------------------------
def saturating_workload(seed: int, scale: int,
                        horizon: float) -> List[Job]:
    """Big-gang demand at ~1.35x federation capacity, arriving inside
    the first half of the horizon and outliving it: member queues stay
    deep, so every cycle does real filter+score placement work at full
    node count — the regime the <=10 % overhead claim is about (an
    unloaded 10k-node cycle is a snapshot no-op that nothing could stay
    within 10 % of)."""
    rng = np.random.default_rng([seed, 0xFED])
    cap0 = (40 + 16) * scale * 8         # type-0 GPUs federation-wide
    cap1 = 16 * scale * 8 + 48 * scale * 4
    jobs: List[Job] = []
    uid = 0
    specs = [  # (gpu_type, n_pods, gpus_per_pod, share of that pool)
        (0, 64, 8, 0.95), (0, 16, 8, 0.80),
        (1, 64, 4, 0.90), (1, 16, 4, 0.85),
    ]
    tenants_by_type = {0: ("tA", "tB", "tC"), 1: ("tC", "tD")}
    for gpu_type, n_pods, per_pod, share in specs:
        demand = share * (cap0 if gpu_type == 0 else cap1)
        count = max(1, int(demand / (n_pods * per_pod)))
        for _ in range(count):
            tenant = str(rng.choice(tenants_by_type[gpu_type]))
            jobs.append(Job(
                uid=uid, tenant=tenant,
                region=TENANT_REGIONS[tenant], gpu_type=gpu_type,
                n_pods=n_pods, gpus_per_pod=per_pod,
                submit_time=float(rng.uniform(0.0, horizon / 2)),
                duration=horizon * 2.0))
            uid += 1
    return jobs


def overhead_gate(seed: int, smoke: bool) -> Dict:
    # 3 x ~10k-node members (the acceptance scale: 10000/8000/12000
    # nodes); --smoke runs the CI version at 3 x ~3k nodes with the
    # same structure and load factor.
    scale = 75 if smoke else 250         # east member: 40*scale nodes
    horizon = 1800.0                     # ~60 scheduling cycles/member
    jobs = saturating_workload(seed, scale, horizon)

    def partition(fed: FederatedCluster) -> List[List[Job]]:
        """The static assignment, computed once on fresh members."""
        sel = StaticPartitionSelect(fed)
        from repro.core.federation import summarize
        summary = summarize(fed.members, 0.0)
        parts: List[List[Job]] = [[] for _ in fed.members]
        for j in jobs:
            parts[sel.assign(j, summary)].append(j)
        return parts

    def run_standalone() -> Tuple[float, int]:
        fed = hetero_members(scale)
        parts = partition(fed)
        elapsed, cycles = 0.0, 0
        for m, part in zip(fed.members, parts):
            import dataclasses
            m.sim_config = dataclasses.replace(m.sim_config,
                                               horizon=horizon)
            sim = Simulator(m.state, m.qsch, m.sim_config)
            part = clone_jobs(part)
            t0 = time.perf_counter()
            res = sim.run(part)
            elapsed += time.perf_counter() - t0
            cycles += res.cycles
        return elapsed, cycles

    def run_federated() -> Tuple[float, int]:
        fed = hetero_members(scale)
        cfg = GSCHConfig(
            select=(QuotaFitSelect(), StaticPartitionSelect(fed)),
            immediate_fit_bonus=0.0,
            # One O(nodes) summary walk per 4 ticks: the `committed`
            # charges bridge staleness, and at 30k total nodes the walk
            # is the only GSCH cost that scales with cluster size.
            summary_max_age_s=120.0,
            spill_deadline_s=horizon * 10)   # scan runs, never fires
        sim = FederatedSimulator(fed, cfg, horizon=horizon)
        batch = clone_jobs(jobs)
        t0 = time.perf_counter()
        res = sim.run(batch)
        return time.perf_counter() - t0, res.cycles

    # Interleave three (standalone, federated) pairs and gate on the
    # best PAIRWISE ratio: pairing adjacent runs cancels slow drift
    # (page-cache state, background load) that min-of-each-side cannot,
    # and the best pair is the least noise-contaminated measurement.
    sa_times, fed_times = [], []
    sa_c = fed_c = 0
    for _ in range(3):
        t, sa_c = run_standalone()
        sa_times.append(t)
        t, fed_c = run_federated()
        fed_times.append(t)
    sa_per = min(sa_times) / max(1, sa_c)
    fed_per = min(fed_times) / max(1, fed_c)
    ratio = min((f / max(1, fed_c)) / (s_ / max(1, sa_c))
                for s_, f in zip(sa_times, fed_times))
    n_nodes = [m.topology.n_nodes for m in hetero_members(scale).members]
    # The 10 % bound is the acceptance criterion at 3 x ~10k nodes,
    # where O(nodes) member cycles dominate the O(members)-per-job
    # routing.  The scaled-down --smoke proxy has ~3x cheaper cycles
    # against the same fixed routing cost, so it gates at a looser
    # bound; the true gate runs at full scale.
    bound = 1.25 if smoke else 1.10
    print(f"--- overhead: members {n_nodes} nodes, "
          f"{sa_c} standalone / {fed_c} federated cycles")
    print(f"    per-cycle: standalone {sa_per * 1e3:.2f} ms   "
          f"federated {fed_per * 1e3:.2f} ms   ratio {ratio:.3f} "
          f"(bound {bound:.2f})")
    assert ratio <= bound, \
        f"federated per-cycle overhead {ratio:.3f} > {bound}"
    return {"nodes_per_member": n_nodes, "standalone_cycles": sa_c,
            "federated_cycles": fed_c,
            "standalone_ms_per_cycle": sa_per * 1e3,
            "federated_ms_per_cycle": fed_per * 1e3, "ratio": ratio,
            "bound": bound}


# ----------------------------------------------------------------------
def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller configs for CI")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the run-wide benchmark seed")
    args = ap.parse_args(argv)
    seed = args.seed if args.seed is not None else bench_seed()
    summary = {
        "seed": seed,
        "parity": parity_gate(seed, args.smoke),
        "spillover": spillover_gate(seed, args.smoke),
        "overhead": overhead_gate(seed, args.smoke),
    }
    write_bench_json("federation", summary)
    print("federation bench: all gates passed")


if __name__ == "__main__":
    main()
