"""Node-score kernel microbenchmark: numpy vs jnp oracle vs Pallas
(interpret) across cluster sizes, plus correctness allclose.

On this CPU container the Pallas kernel runs in interpret mode (orders of
magnitude slower — it executes the kernel body in Python); the number
that matters here is the *jit'd oracle* throughput and the agreement of
all three backends.  On TPU the compiled kernel streams the node table
through VMEM in (64, 128) blocks."""

import time

import numpy as np

from repro.core.scoring import E_BINPACK, node_scores_np
from repro.kernels.ops import node_scores


def bench_once(n: int, iters: int = 50) -> dict:
    rng = np.random.default_rng(0)
    free = rng.integers(0, 9, size=n).astype(np.int32)
    used = (8 - free).astype(np.int32)
    mask = rng.random(n) < 0.9
    gl = rng.random(n).astype(np.float32)
    tp = rng.random(n).astype(np.float32)
    kw = dict(request=4, gpus_per_node=8, weights=E_BINPACK)

    t0 = time.perf_counter()
    for _ in range(iters):
        ref_np = node_scores_np(free, used, mask, gl, tp, 4, 8, E_BINPACK)
    t_np = (time.perf_counter() - t0) / iters

    out = node_scores(free, used, mask, gl, tp, backend="ref", **kw)
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = node_scores(free, used, mask, gl, tp, backend="ref", **kw)
        out.block_until_ready()
    t_jnp = (time.perf_counter() - t0) / iters

    pal = node_scores(free, used, mask, gl, tp, backend="interpret", **kw)
    np.testing.assert_allclose(np.asarray(pal), ref_np, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out), ref_np, rtol=1e-6)
    return {"n": n, "numpy_us": t_np * 1e6, "jnp_us": t_jnp * 1e6}


def bench_wkv6() -> dict:
    """wkv6 kernel: jnp-oracle throughput + interpret-mode agreement, and
    the analytic HBM-traffic ratio the kernel buys on TPU (state stays in
    VMEM: O(T n^2) state round-trips -> O(T n) streams)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import wkv6

    B, T, H, n = 4, 256, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r, k, v = (jax.random.normal(ki, (B, T, H, n)) * 0.5 for ki in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, n)))
    u = jax.random.normal(ks[4], (H, n)) * 0.5
    s0 = jnp.zeros((B, H, n, n), jnp.float32)

    o_ref, sT_ref = wkv6(r, k, v, w, u, s0, backend="ref")
    jax.block_until_ready(o_ref)
    t0 = time.perf_counter()
    for _ in range(5):
        o_ref, sT_ref = wkv6(r, k, v, w, u, s0, backend="ref")
        jax.block_until_ready(o_ref)
    t_ref = (time.perf_counter() - t0) / 5

    o_pl, sT_pl = wkv6(r, k, v, w, u, s0, backend="interpret", tb=64)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               atol=1e-5, rtol=1e-5)
    state_bytes = 3 * T * B * H * n * n * 4          # ~3 round-trips/step
    stream_bytes = 5 * B * T * H * n * 4
    print(f"wkv6 (B{B} T{T} H{H} n{n}): jnp scan {t_ref*1e3:.1f} ms, "
          f"interpret==ref asserted; analytic HBM ratio "
          f"state/stream = {state_bytes / stream_bytes:.0f}x")
    return {"t_ref_ms": t_ref * 1e3,
            "traffic_ratio": state_bytes / stream_bytes}


def main() -> list:
    rows = []
    print("nodes    numpy(us)   jnp-jit(us)")
    for n in (1000, 10_000, 100_000):
        r = bench_once(n)
        rows.append(r)
        print(f"{r['n']:6d}  {r['numpy_us']:10.1f}  {r['jnp_us']:11.1f}")
    print("(pallas interpret-mode agreement asserted at every size)")
    rows.append(bench_wkv6())
    return rows


if __name__ == "__main__":
    main()
