"""§3.4 scaling: the million-node scheduling core.

The paper's central engineering claim is that Kant sustains scheduling
efficiency "in clusters ranging from hundreds to tens of thousands of
GPUs".  The hot loop is gang placement; this benchmark tracks three
generations of it:

* **sequential** — one full filter+score pass per pod (the seed);
* **legacy batched** — ONE fused pass + lazy-greedy heap slot selection
  (PR 1; ``RSCHConfig(subset_scoring=False, slot_engine="heap")``);
* **SoA core** (this PR's defaults) — structure-of-arrays cluster
  columns, O(groups) tracked-aggregate preselection, subset level-2
  scoring over the selected NodeNetGroups only, and the vectorized
  top-k slot-chain engine (``slot_engine="topk"``).

All three provably pick identical nodes; every A/B below asserts it.

Measured and gated:

* per-cycle scheduling latency at 1k / 10k / 100k / 1M nodes (64-pod
  gang, realistically fragmented snapshot);
* **>= 3x** SoA speedup over legacy batched at 100k nodes, and SoA
  **no slower than** legacy at 10k (the "<= PR-1 numbers" gate);
* legacy gates carried forward: batched >= 5x sequential at 10k,
  plugin-profile parity within 5%;
* end-to-end byte-identity: full simulator runs across the
  policy x strategy matrix at 1k and 10k nodes, SoA defaults vs the
  legacy engine — identical placements, start times and pod GPU sets;
* **pipelined trace replay**: a multi-day training trace through the
  simulator with ``pipelined_cycles`` off vs on — placements must be
  identical; reports replay throughput, speculation hit/conflict
  stats, and the critical-path per-cycle time (cycle cost minus the
  speculative work that overlaps binding I/O in a real deployment);
* ``--check-regression``: compares this run's per-cycle latencies to
  the committed ``BENCH_sched_scale.json`` baseline and fails on a
  >25% regression at any common size.

Usage::

    PYTHONPATH=src python benchmarks/sched_scale_bench.py \
        [--smoke] [--check-regression]

``--smoke`` trims node counts and repeat counts for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/sched_scale_bench.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro.core import (ClusterState, Job, JobKind, QSCH, QSCHConfig,
                        QueuePolicy, QuotaManager, RSCH, RSCHConfig,
                        SimConfig, Simulator, Strategy, default_profiles)
from repro.core.snapshot import FullSnapshotter
from repro.core.topology import ClusterTopology

from benchmarks.common import bench_seed, write_bench_json

GANG_PODS = 64
GPUS_PER_POD = 8

# PR-1 behavior: full-width level-2 scoring + heap slot selection.
LEGACY = dict(subset_scoring=False, slot_engine="heap")


def make_state(n_nodes: int, seed: int = 0) -> ClusterState:
    """A fragmented cluster: ~60% of nodes partially or fully busy.

    Vectorized setup — the old per-node loop took minutes at 1M nodes;
    one broadcast writes the same busy pattern in O(n) numpy.
    """
    topo = ClusterTopology(
        n_nodes=n_nodes, gpus_per_node=8, nodes_per_leaf=32,
        leaves_per_spine=4, spines_per_superspine=4, nodes_per_hbd=32)
    state = ClusterState.create(topo)
    rng = np.random.default_rng(seed)
    busy_nodes = rng.random(n_nodes) < 0.6
    busy_count = rng.integers(1, 9, size=n_nodes)
    state.gpu_busy[:] = ((np.arange(8) < busy_count[:, None])
                         & busy_nodes[:, None])
    return state


def bench_one(state: ClusterState, repeats: int, *, profiles=None,
              **cfg_kw) -> tuple[float, list[list[int]]]:
    """Best-of-N per-cycle latency (s) and the node picks of each cycle.

    Minimum over repeats is the standard noise-robust estimator for a
    deterministic microbenchmark."""
    rsch = RSCH(state.topology,
                RSCHConfig(train_strategy=Strategy.E_BINPACK, **cfg_kw),
                profiles=profiles)
    snap = FullSnapshotter().take(state)
    job = Job(uid=1, tenant="bench", gpu_type=0, n_pods=GANG_PODS,
              gpus_per_pod=GPUS_PER_POD, kind=JobKind.TRAIN)
    times, picks = [], []
    rsch.schedule(job, snap)                      # warm caches
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = rsch.schedule(job, snap)
        times.append(time.perf_counter() - t0)
        assert result.placement is not None, "bench job must be placeable"
        picks.append([(p.node, p.gpu_indices, p.nic)
                      for p in result.placement.pods])
    return float(np.min(times)), picks


def bench_pair(state: ClusterState, repeats: int
               ) -> tuple[float, float, list]:
    """Interleaved best-of-N timing: legacy-shim RSCH vs explicit
    default profiles, alternating per iteration so load drift hits both
    equally.  Returns (t_legacy, t_profiles, profile picks)."""
    snap = FullSnapshotter().take(state)
    job = Job(uid=1, tenant="bench", gpu_type=0, n_pods=GANG_PODS,
              gpus_per_pod=GPUS_PER_POD, kind=JobKind.TRAIN)
    legacy = RSCH(state.topology,
                  RSCHConfig(train_strategy=Strategy.E_BINPACK))
    explicit = RSCH(state.topology,
                    RSCHConfig(train_strategy=Strategy.E_BINPACK),
                    profiles=default_profiles())
    legacy.schedule(job, snap)                    # warm caches
    explicit.schedule(job, snap)
    t_leg, t_prof, picks = [], [], []
    for _ in range(repeats * 2):
        t0 = time.perf_counter()
        legacy.schedule(job, snap)
        t_leg.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        result = explicit.schedule(job, snap)
        t_prof.append(time.perf_counter() - t0)
        picks.append([(p.node, p.gpu_indices, p.nic)
                      for p in result.placement.pods])
    return float(np.min(t_leg)), float(np.min(t_prof)), picks


# ----------------------------------------------------------------------
# End-to-end byte-identity: simulator runs across policy x strategy
# ----------------------------------------------------------------------
def _matrix_jobs(rng, n, max_pods):
    return [Job(uid=i, tenant=f"t{i % 3}", gpu_type=0,
                n_pods=int(rng.integers(1, max_pods + 1)),
                gpus_per_pod=int(rng.choice([1, 2, 4, 8])),
                duration=float(rng.integers(300, 6000)),
                submit_time=float(rng.integers(0, 1800)),
                priority=int(rng.integers(0, 3)),
                kind=JobKind.TRAIN) for i in range(n)]


def _placement_key(jobs):
    out = []
    for j in sorted(jobs, key=lambda j: j.uid):
        if j.placement is None:
            out.append((j.uid, j.start_time, None))
        else:
            out.append((j.uid, j.start_time,
                        tuple((p.node, tuple(p.gpu_indices))
                              for p in j.placement.pods)))
    return out


def _run_sim(n_nodes, policy, strategy, *, rsch_kw=None, n_jobs=48,
             seed=0, pipelined=False):
    topo = ClusterTopology(
        n_nodes=n_nodes, gpus_per_node=8, nodes_per_leaf=32,
        leaves_per_spine=4, spines_per_superspine=4, nodes_per_hbd=32)
    state = ClusterState.create(topo)
    quota = QuotaManager({f"t{i}": {0: 10 ** 9} for i in range(3)})
    rsch = RSCH(topo, RSCHConfig(train_strategy=strategy,
                                 **(rsch_kw or {})))
    qsch = QSCH(quota, rsch, QSCHConfig(policy=policy))
    sim = Simulator(state, qsch,
                    SimConfig(pipelined_cycles=pipelined))
    rng = np.random.default_rng(seed)
    max_pods = max(2, n_nodes // 16)
    t0 = time.perf_counter()
    res = sim.run(_matrix_jobs(rng, n_jobs, min(max_pods, 8)))
    wall = time.perf_counter() - t0
    return _placement_key(res.jobs), res, wall


def identity_matrix(sizes, n_jobs, seed) -> int:
    """SoA defaults vs the legacy engine across policy x strategy at
    each size: full-run placements must be byte-identical."""
    checked = 0
    for n in sizes:
        for policy in QueuePolicy:
            for strategy in Strategy:
                a, _, _ = _run_sim(n, policy, strategy, rsch_kw=LEGACY,
                                   n_jobs=n_jobs, seed=seed)
                b, _, _ = _run_sim(n, policy, strategy,
                                   n_jobs=n_jobs, seed=seed)
                assert a == b, (
                    f"SoA engine diverged from legacy: {n} nodes, "
                    f"{policy.value}, {strategy.value}")
                checked += 1
    return checked


# ----------------------------------------------------------------------
# Pipelined multi-day trace replay
# ----------------------------------------------------------------------
def trace_replay(n_nodes: int, n_jobs: int, seed: int) -> dict:
    """Replay a multi-day contended training trace with pipelining off
    vs on: placements must match; report throughput + pipeline stats."""
    rng = np.random.default_rng(seed)
    # ~2 simulated days of arrivals, enough width to keep a backlog.
    jobs = [Job(uid=i, tenant=f"t{i % 4}", gpu_type=0,
                n_pods=int(rng.integers(1, 9)),
                gpus_per_pod=int(rng.choice([4, 8])),
                duration=float(rng.integers(1800, 40000)),
                submit_time=float(rng.integers(0, 172800)),
                priority=int(rng.integers(0, 3)),
                kind=JobKind.TRAIN) for i in range(n_jobs)]

    def replay(pipelined):
        topo = ClusterTopology(
            n_nodes=n_nodes, gpus_per_node=8, nodes_per_leaf=32,
            leaves_per_spine=4, spines_per_superspine=4,
            nodes_per_hbd=32)
        state = ClusterState.create(topo)
        quota = QuotaManager({f"t{i}": {0: 10 ** 9} for i in range(4)})
        rsch = RSCH(topo,
                    RSCHConfig(train_strategy=Strategy.E_BINPACK))
        qsch = QSCH(quota, rsch, QSCHConfig(policy=QueuePolicy.BACKFILL))
        sim = Simulator(state, qsch,
                        SimConfig(pipelined_cycles=pipelined))
        t0 = time.perf_counter()
        res = sim.run([Job(uid=j.uid, tenant=j.tenant, gpu_type=0,
                           n_pods=j.n_pods, gpus_per_pod=j.gpus_per_pod,
                           duration=j.duration,
                           submit_time=j.submit_time,
                           priority=j.priority, kind=j.kind)
                       for j in jobs])
        wall = time.perf_counter() - t0
        return _placement_key(res.jobs), res, wall

    base_key, base_res, base_wall = replay(False)
    pipe_key, pipe_res, pipe_wall = replay(True)
    assert base_key == pipe_key, (
        "pipelined replay diverged from sequential replay")
    stats = pipe_res.pipeline
    cycles = max(1, pipe_res.cycles)
    per_cycle = pipe_wall / cycles
    # Speculative work overlaps binding I/O in a pipelined deployment;
    # what remains on the critical path is the cycle cost minus it.
    critical = max(0.0, pipe_wall - stats["spec_seconds"]) / cycles
    return {
        "n_nodes": n_nodes, "n_jobs": len(jobs),
        "cycles": pipe_res.cycles,
        "baseline_wall_s": base_wall,
        "pipelined_wall_s": pipe_wall,
        "cycles_per_s": cycles / pipe_wall,
        "jobs_per_s": len(jobs) / pipe_wall,
        "per_cycle_ms": per_cycle * 1e3,
        "critical_path_per_cycle_ms": critical * 1e3,
        "speculated": stats["speculated"], "hits": stats["hits"],
        "conflicts": stats["conflicts"], "misses": stats["misses"],
        "errors": stats["errors"],
        "spec_seconds": stats["spec_seconds"],
    }


# ----------------------------------------------------------------------
# Regression guard vs the committed baseline
# ----------------------------------------------------------------------
BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_sched_scale.json")
REGRESSION_TOLERANCE = 1.25


def check_regression(rows: dict, baseline_path: str = BASELINE_PATH
                     ) -> list:
    """Fail on a >25% per-cycle regression vs the committed baseline at
    any size both runs measured.

    The gated metric is the SoA-over-legacy speedup, not raw wall
    time: both paths are timed in the SAME run, so the ratio cancels
    machine speed and the guard works on any CI runner — while still
    catching changes that slow the SoA core relative to the frozen
    legacy engine.  Raw per-cycle ms is reported alongside for eyes.
    """
    if not os.path.exists(baseline_path):
        print(f"    [regression] no baseline at {baseline_path}; "
              f"skipping (commit one to arm the guard)")
        return []
    with open(baseline_path) as f:
        base = json.load(f).get("per_cycle", {})
    table = []
    failures = []
    for size, row in rows.items():
        if size < 10_000:
            # Below 10k both engines finish in well under a millisecond
            # and the speedup ratio is timer jitter, not signal — the
            # subset-scoring win only separates from noise at scale.
            continue
        ref = base.get(str(size)) or base.get(size)
        if not ref or "soa_speedup" not in ref:
            continue
        rel = ref["soa_speedup"] / row["soa_speedup"]
        table.append({"nodes": int(size),
                      "baseline_ms": ref["soa_s"] * 1e3,
                      "current_ms": row["soa_s"] * 1e3,
                      "baseline_speedup": ref["soa_speedup"],
                      "current_speedup": row["soa_speedup"],
                      "relative_slowdown": rel})
        flag = "REGRESSION" if rel > REGRESSION_TOLERANCE else "ok"
        print(f"    [regression] {size:>8} nodes: speedup "
              f"{ref['soa_speedup']:.2f}x -> {row['soa_speedup']:.2f}x "
              f"(rel {rel:.2f}); per-cycle {ref['soa_s'] * 1e3:.2f}ms -> "
              f"{row['soa_s'] * 1e3:.2f}ms  {flag}")
        if rel > REGRESSION_TOLERANCE:
            failures.append((size, rel))
    assert not failures, (
        f"SoA per-cycle regression >25% vs committed baseline "
        f"(size, relative slowdown): {failures}")
    return table


def run_bench(smoke: bool = False, regression: bool = False) -> dict:
    seed = bench_seed()
    if smoke:
        sizes = (1000, 10_000)
        matrix_sizes = (1000,)
        repeats, matrix_jobs = 9, 32
        replay_nodes, replay_jobs = 128, 300
    else:
        sizes = (1000, 10_000, 100_000, 1_000_000)
        matrix_sizes = (1000, 10_000)
        repeats, matrix_jobs = 15, 48
        replay_nodes, replay_jobs = 256, 800

    rows = {}
    print(f"{'nodes':>8s} {'sequential':>12s} {'legacy':>12s} "
          f"{'SoA':>12s} {'SoA/legacy':>10s} {'pods/s (SoA)':>13s}")
    for n in sizes:
        state = make_state(n, seed=seed)
        t_leg, picks_leg = bench_one(state, repeats, **LEGACY)
        t_soa, picks_soa = bench_one(state, repeats)
        assert picks_leg == picks_soa, (
            f"SoA placement diverged from legacy batched at {n} nodes")
        row = {"legacy_s": t_leg, "soa_s": t_soa,
               "soa_speedup": t_leg / t_soa,
               "placements_per_s": GANG_PODS / t_soa}
        if n <= 10_000:
            # Seed-era sequential loop: 64 full passes per gang.  Too
            # slow to time beyond 10k, where batched is the only game.
            t_seq, picks_seq = bench_one(state, repeats,
                                         batched_gang=False, **LEGACY)
            assert picks_seq == picks_leg, (
                f"batched placement diverged from sequential at {n} "
                f"nodes")
            row["sequential_s"] = t_seq
            row["batched_speedup"] = t_seq / t_leg
            # Plugin-framework parity (api_redesign acceptance gate):
            # interleaved timing so load drift hits both paths equally.
            t_bat2, t_prof, picks_prof = bench_pair(state, repeats)
            assert all(p == picks_soa[0] for p in picks_prof), (
                f"profile-built RSCH diverged at {n} nodes")
            row["profile_s"] = t_prof
            row["profile_overhead"] = t_prof / t_bat2 - 1.0
            # 100us absolute floor: the SoA path is fast enough at 1k
            # nodes that a relative-only bound measures timer jitter.
            assert t_prof <= max(t_bat2 * 1.05, t_bat2 + 100e-6), (
                f"profile engine must stay within 5% of the batched "
                f"path at {n} nodes, got {row['profile_overhead']:+.1%}")
        seq = row.get("sequential_s")
        print(f"{n:8d} "
              + (f"{seq * 1e3:10.2f}ms" if seq else f"{'—':>12s}")
              + f" {t_leg * 1e3:10.2f}ms {t_soa * 1e3:10.2f}ms "
              f"{row['soa_speedup']:9.1f}x "
              f"{GANG_PODS / t_soa:11.0f}/s")
        rows[n] = row

    bar = rows.get(10_000)
    if bar is not None and "batched_speedup" in bar:
        assert bar["batched_speedup"] >= 5.0, (
            f"batched gang placement must be >=5x faster than "
            f"sequential at 10k nodes, got {bar['batched_speedup']:.1f}x")
        # "<= PR-1 numbers" gate: the SoA defaults may not cost more
        # than the legacy batched path at 10k (5% timer-noise floor).
        assert bar["soa_s"] <= bar["legacy_s"] * 1.05, (
            f"SoA core slower than legacy batched at 10k nodes: "
            f"{bar['soa_s'] * 1e3:.2f}ms vs {bar['legacy_s'] * 1e3:.2f}ms")
        print(f"[ok] 10k: batched {bar['batched_speedup']:.1f}x >= 5x "
              f"sequential; SoA {bar['soa_speedup']:.2f}x legacy")
    big = rows.get(100_000)
    if big is not None:
        assert big["soa_speedup"] >= 3.0, (
            f"SoA core must be >=3x faster than legacy batched at 100k "
            f"nodes, got {big['soa_speedup']:.1f}x")
        print(f"[ok] 100k: SoA {big['soa_speedup']:.1f}x >= 3x legacy")
    giant = rows.get(1_000_000)
    if giant is not None:
        print(f"[ok] 1M-node per-cycle: {giant['soa_s'] * 1e3:.1f}ms "
              f"({giant['placements_per_s']:.0f} pods/s)")

    checked = identity_matrix(matrix_sizes, matrix_jobs, seed)
    print(f"[ok] policy x strategy identity matrix: {checked} "
          f"simulator A/Bs byte-identical "
          f"(sizes {list(matrix_sizes)})")

    replay = trace_replay(replay_nodes, replay_jobs, seed)
    hit_pool = max(1, replay["hits"] + replay["misses"])
    print(f"[ok] pipelined trace replay ({replay['n_nodes']} nodes, "
          f"{replay['n_jobs']} jobs, {replay['cycles']} cycles): "
          f"placements identical; {replay['cycles_per_s']:.0f} "
          f"cycles/s; per-cycle {replay['per_cycle_ms']:.2f}ms -> "
          f"critical path {replay['critical_path_per_cycle_ms']:.2f}ms; "
          f"spec hit rate {replay['hits']}/{hit_pool}, "
          f"{replay['conflicts']} conflicts, {replay['errors']} errors")

    payload = {"per_cycle": {str(k): v for k, v in rows.items()},
               "identity_matrix_runs": checked,
               "trace_replay": replay,
               "smoke": smoke, "seed": seed}
    if regression:
        payload["regression"] = check_regression(rows)
    write_bench_json("sched_scale", payload)
    return rows


def main(argv=None) -> dict:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--smoke", action="store_true",
                        help="trimmed sizes/repeats for CI")
    parser.add_argument("--check-regression", action="store_true",
                        help="fail on >25% per-cycle regression vs the "
                             "committed BENCH_sched_scale.json")
    args = parser.parse_args(argv)
    return run_bench(smoke=args.smoke, regression=args.check_regression)


if __name__ == "__main__":
    main()
    sys.exit(0)
