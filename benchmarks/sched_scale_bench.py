"""§3.4 scaling: batched gang placement vs the sequential per-pod loop.

The paper's central engineering claim is that Kant sustains scheduling
efficiency "in clusters ranging from hundreds to tens of thousands of
GPUs".  The hot loop is gang placement: the seed reproduction re-scored
the full node table once per pod, so a 64-pod gang on a 10k-node cluster
cost 64 full passes per cycle.  The batched engine does ONE fused
filter+score pass plus heap-based capacity-aware slot selection
(``repro.core.scoring.select_gang_slots``) and provably picks the same
nodes.

This benchmark measures, at 1k / 10k / 50k nodes:

* per-cycle scheduling latency (one ``RSCH.schedule`` of a 64-pod gang
  against a realistically fragmented snapshot);
* placements/sec (pods placed per second of scheduler CPU);
* the speedup of batched over sequential — asserted >= 5x at 10k nodes,
  the acceptance bar for this optimization;
* placement equivalence: batched and sequential must pick identical
  node sequences on every measured cycle;
* plugin-framework parity: an RSCH built from explicit default
  profiles (``repro.core.framework``) must produce *byte-identical*
  placements to the legacy ``Strategy`` shim, with per-cycle time
  within 5% — the framework refactor may not tax the fused batched
  path.

Usage::

    PYTHONPATH=src python benchmarks/sched_scale_bench.py [--smoke]

``--smoke`` trims the node counts and repeat counts for CI.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import (ClusterState, Job, JobKind, RSCH, RSCHConfig,
                        Strategy, default_profiles)
from repro.core.snapshot import FullSnapshotter
from repro.core.topology import ClusterTopology


GANG_PODS = 64
GPUS_PER_POD = 8


def make_state(n_nodes: int, seed: int = 0) -> ClusterState:
    """A fragmented cluster: ~60% of nodes partially or fully busy."""
    topo = ClusterTopology(
        n_nodes=n_nodes, gpus_per_node=8, nodes_per_leaf=32,
        leaves_per_spine=4, spines_per_superspine=4, nodes_per_hbd=32)
    state = ClusterState.create(topo)
    rng = np.random.default_rng(seed)
    busy_nodes = rng.random(n_nodes) < 0.6
    busy_count = rng.integers(1, 9, size=n_nodes)
    for node in np.nonzero(busy_nodes)[0]:
        state.gpu_busy[node, :busy_count[node]] = True
    return state


def bench_one(state: ClusterState, batched: bool, repeats: int,
              profiles=None) -> tuple[float, list[list[int]]]:
    """Best-of-N per-cycle latency (s) and the node picks of each cycle.

    Minimum over repeats is the standard noise-robust estimator for a
    deterministic microbenchmark."""
    rsch = RSCH(state.topology,
                RSCHConfig(train_strategy=Strategy.E_BINPACK,
                           batched_gang=batched),
                profiles=profiles)
    snap = FullSnapshotter().take(state)
    job = Job(uid=1, tenant="bench", gpu_type=0, n_pods=GANG_PODS,
              gpus_per_pod=GPUS_PER_POD, kind=JobKind.TRAIN)
    times, picks = [], []
    rsch.schedule(job, snap)                      # warm caches
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = rsch.schedule(job, snap)
        times.append(time.perf_counter() - t0)
        assert result.placement is not None, "bench job must be placeable"
        picks.append([(p.node, p.gpu_indices, p.nic)
                      for p in result.placement.pods])
    return float(np.min(times)), picks


def bench_pair(state: ClusterState, repeats: int
               ) -> tuple[float, float, list]:
    """Interleaved best-of-N timing: legacy-shim RSCH vs explicit
    default profiles, alternating per iteration so load drift hits both
    equally.  Returns (t_legacy, t_profiles, profile picks)."""
    snap = FullSnapshotter().take(state)
    job = Job(uid=1, tenant="bench", gpu_type=0, n_pods=GANG_PODS,
              gpus_per_pod=GPUS_PER_POD, kind=JobKind.TRAIN)
    legacy = RSCH(state.topology,
                  RSCHConfig(train_strategy=Strategy.E_BINPACK))
    explicit = RSCH(state.topology,
                    RSCHConfig(train_strategy=Strategy.E_BINPACK),
                    profiles=default_profiles())
    legacy.schedule(job, snap)                    # warm caches
    explicit.schedule(job, snap)
    t_leg, t_prof, picks = [], [], []
    for _ in range(repeats * 2):
        t0 = time.perf_counter()
        legacy.schedule(job, snap)
        t_leg.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        result = explicit.schedule(job, snap)
        t_prof.append(time.perf_counter() - t0)
        picks.append([(p.node, p.gpu_indices, p.nic)
                      for p in result.placement.pods])
    return float(np.min(t_leg)), float(np.min(t_prof)), picks


def main(smoke: bool = False) -> dict:
    sizes = (1000, 10_000) if smoke else (1000, 10_000, 50_000)
    repeats = 5 if smoke else 15
    rows = {}
    print(f"{'nodes':>7s} {'sequential':>12s} {'batched':>12s} "
          f"{'speedup':>8s} {'pods/s (batched)':>17s}")
    for n in sizes:
        state = make_state(n)
        t_seq, picks_seq = bench_one(state, batched=False, repeats=repeats)
        t_bat, picks_bat = bench_one(state, batched=True, repeats=repeats)
        assert picks_seq == picks_bat, (
            f"batched placement diverged from sequential at {n} nodes")
        # Plugin-framework parity (acceptance gate of the api_redesign):
        # explicit default profiles vs the legacy shim — byte-identical
        # placements, per-cycle time within 5% of the batched path.
        # The two paths are timed interleaved so machine-load drift
        # between separate loops cannot fake an overhead.
        t_bat2, t_prof, picks_prof = bench_pair(state, repeats)
        assert all(p == picks_bat[0] for p in picks_prof), (
            f"profile-built RSCH diverged from the legacy shim at {n} "
            f"nodes")
        overhead = t_prof / t_bat2 - 1.0
        speedup = t_seq / t_bat
        rows[n] = {"sequential_s": t_seq, "batched_s": t_bat,
                   "profile_s": t_prof, "profile_overhead": overhead,
                   "speedup": speedup,
                   "placements_per_s": GANG_PODS / t_bat}
        print(f"{n:7d} {t_seq * 1e3:10.2f}ms {t_bat * 1e3:10.2f}ms "
              f"{speedup:7.1f}x {GANG_PODS / t_bat:15.0f}/s"
              f"   profiles {t_prof * 1e3:.2f}ms ({overhead:+.1%})")
        if n <= 10_000:
            assert t_prof <= t_bat2 * 1.05, (
                f"profile engine must stay within 5% of the batched "
                f"path at {n} nodes, got {overhead:+.1%}")
    bar = rows.get(10_000)
    if bar is not None:
        assert bar["speedup"] >= 5.0, (
            f"batched gang placement must be >=5x faster than sequential "
            f"at 10k nodes, got {bar['speedup']:.1f}x")
        print(f"[ok] 10k-node 64-pod gang: {bar['speedup']:.1f}x >= 5x, "
              f"placements equivalent")
    return rows


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--smoke", action="store_true",
                        help="trimmed sizes/repeats for CI")
    args = parser.parse_args()
    main(smoke=args.smoke)
    sys.exit(0)
