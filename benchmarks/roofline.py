"""§Roofline: render the (arch × shape × mesh) table from the dry-run
JSONs in ``experiments/dryrun/`` (deliverable g).

Run ``python -m repro.launch.dryrun --arch all --shape all`` (and with
``--multi-pod``) first; this module only reads the recorded artifacts.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

OUT_DIR = "experiments/dryrun"


def load_results(out_dir: str = OUT_DIR, rules: str = "baseline"
                 ) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("rules", "baseline") == rules:
            rows.append(r)
    return rows


def fmt_row(r: Dict) -> str:
    return (f"{r['arch']:26s} {r['shape']:11s} {r['mesh']:8s} "
            f"{r['compute_term_s']:>10.3e} {r['memory_term_s']:>10.3e} "
            f"{r['collective_term_s']:>10.3e}  {r['dominant_term']:>10s} "
            f"{r['useful_flops_ratio']:>7.3f}")


def main(rules: str = "baseline") -> List[Dict]:
    rows = load_results(rules=rules)
    if not rows:
        print(f"no dry-run artifacts under {OUT_DIR} — run "
              "`python -m repro.launch.dryrun` first")
        return []
    hdr = (f"{'arch':26s} {'shape':11s} {'mesh':8s} "
           f"{'compute(s)':>10s} {'memory(s)':>10s} {'collect(s)':>10s}  "
           f"{'dominant':>10s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(fmt_row(r))
    n_single = sum(1 for r in rows if r["mesh"] == "16x16")
    n_multi = sum(1 for r in rows if r["mesh"] == "2x16x16")
    print(f"\n{n_single} single-pod + {n_multi} multi-pod combinations "
          f"compiled (rules={rules})")
    doms = {}
    for r in rows:
        doms[r["dominant_term"]] = doms.get(r["dominant_term"], 0) + 1
    print("dominant-term histogram:", doms)
    return rows


if __name__ == "__main__":
    main()
