"""Self-tuning benchmark: inert when idle, profitable when active,
transferable across clusters, cheap at scale.

Four gates, matching the tuning subsystem's acceptance criteria:

1. **Byte-identity** — a :class:`repro.core.TuningManager` attached
   with a :class:`NoOpController` must not perturb the simulation:
   across a policy x strategy matrix, placements, metric reports and
   the raw sample series are identical to the detached run, and the
   param-change log stays empty.
2. **Tuned vs static** — on a contended multi-priority drain trace
   (large low-priority gangs behind a stream of small normal-priority
   jobs), the tuned controller stack (starvation escalator + guarded
   hill climb) must beat EVERY static Table-1 profile on at least one
   frontier metric (GAR, mean GFR, P90 JWTD, goodput) without
   regressing any other beyond a per-metric noise tolerance.
3. **Warm-start transfer** — a federation member warm-started from a
   donor member's exported :class:`repro.core.TuningProfile` reaches
   the donor's tuned operating point (L-inf distance in range-
   normalized parameter space) in measurably fewer control periods
   than an identical cold-started member.
4. **Attached overhead** — with the manager attached and its tick-path
   live (wait harvest + controller scans), the per-cycle scheduling
   cost on a fragmented 10k-node cluster stays within **5%** of the
   detached cycle, measured by the median of paired per-iteration
   deltas on one shared stack.

Writes ``BENCH_tuning.json`` (uploaded as a CI artifact).
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/tuning_bench.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import (bench_seed, clone_jobs, scale_topology,
                               write_bench_json)  # noqa: E402
from benchmarks.obs_bench import (GANG_PODS, _cycle_stack,
                                  placement_fingerprint,
                                  sample_series)  # noqa: E402
from repro.core import (ClusterState, Event, EventKind, FederatedCluster,
                        FederatedSimulator, HillClimbController, Job,
                        JobKind, NoOpController, PRIO_LOW, PRIO_NORMAL,
                        QSCH, QSCHConfig, QueuePolicy, QuotaManager,
                        RSCH, RSCHConfig, SimConfig, Simulator, SimResult,
                        StarvationEscalator, Strategy, TuningManager,
                        make_member, training_trace,
                        waiting_percentile)  # noqa: E402

CONTROL_PERIOD_S = 1800.0


def run_sim(jobs: Sequence[Job], *, policy=QueuePolicy.BACKFILL,
            strategy=Strategy.E_BINPACK, n_gpus: int = 512,
            manager: Optional[TuningManager] = None,
            preempt: bool = True,
            horizon: Optional[float] = None) -> SimResult:
    topo = scale_topology(n_gpus=n_gpus)
    state = ClusterState.create(topo)
    qm = QuotaManager({"t0": {0: 10**6}})
    rsch = RSCH(topo, RSCHConfig(train_strategy=strategy))
    qsch = QSCH(qm, rsch, QSCHConfig(policy=policy,
                                     priority_preemption=preempt))
    sim = Simulator(state, qsch,
                    SimConfig(tick_interval=30.0, sample_interval=300.0,
                              binding_latency=45.0, horizon=horizon))
    if manager is not None:
        manager.attach(sim)
    return sim.run(clone_jobs(jobs))


# ----------------------------------------------------------------------
# 1. Byte-identity: an attached no-op manager must not perturb the run
# ----------------------------------------------------------------------
def identity_gate(seed: int, smoke: bool) -> Dict:
    jobs = training_trace(80 if smoke else 160, seed=seed,
                          arrival_rate_per_hour=500,
                          mean_duration_s=2400.0)
    jobs = [j for j in jobs if j.n_gpus <= 128]
    configs = [(QueuePolicy.BACKFILL, Strategy.E_BINPACK),
               (QueuePolicy.STRICT_FIFO, Strategy.BINPACK),
               (QueuePolicy.BEST_EFFORT_FIFO, Strategy.E_BINPACK)]
    if not smoke:
        configs += [(QueuePolicy.BACKFILL, Strategy.BINPACK),
                    (QueuePolicy.STRICT_FIFO, Strategy.E_BINPACK),
                    (QueuePolicy.BEST_EFFORT_FIFO, Strategy.BINPACK)]
    handles = 0
    for policy, strategy in configs:
        base = run_sim(jobs, policy=policy, strategy=strategy)
        noop = NoOpController()
        mgr = TuningManager([noop], control_period_s=CONTROL_PERIOD_S)
        inst = run_sim(jobs, policy=policy, strategy=strategy,
                       manager=mgr)
        tag = f"{policy.name} x {strategy.name}"
        assert placement_fingerprint(base) == placement_fingerprint(
            inst), f"no-op manager perturbed placements: {tag}"
        assert base.metrics.report() == inst.metrics.report(), \
            f"no-op manager perturbed the metric report: {tag}"
        assert sample_series(base) == sample_series(inst), \
            f"no-op manager perturbed the raw sample series: {tag}"
        assert noop.ticks_seen > 0 and noop.windows_seen > 0, \
            f"manager never drove the controller: {tag}"
        assert not mgr.space.changes, \
            f"no-op run wrote {len(mgr.space.changes)} param changes"
        handles = len(mgr.space)
        assert handles >= 15, \
            f"expected a full tunable surface, got {handles} handles"
    print(f"--- identity: {len(configs)} policy x strategy configs "
          f"byte-identical with an attached no-op manager "
          f"({handles} tunable handles bound)")
    return {"configs_checked": len(configs), "handles": handles}


# ----------------------------------------------------------------------
# 2. Tuned controller vs the static Table-1 profiles
# ----------------------------------------------------------------------
def contended_trace(seed: int, smoke: bool, n_gpus: int) -> List[Job]:
    """Starvation-shaped contention: a substantial PRIO_LOW class
    (8/16-GPU pods, ~2.4x cluster capacity) bursts in at t=0 under a
    continuous stream of small PRIO_NORMAL jobs.  Priority ordering
    keeps the stream ahead of the queued low jobs, so without
    escalation they only drain through leftover capacity for hours —
    their waits dominate the P90 JWTD."""
    rng = np.random.default_rng(seed)
    window = 4.0 * 3600.0
    jobs: List[Job] = []
    # Normal-priority stream: ~55% average utilization on its own.
    n_norm = round(0.55 * n_gpus * window / (4.9 * 2400.0))
    inter = rng.exponential(window / n_norm, size=n_norm)
    arrivals = np.cumsum(inter)
    for i in range(n_norm):
        gpus = int(rng.choice([1, 2, 4, 8, 16], p=[.2, .25, .25, .2, .1]))
        n_pods, per_pod = (1, gpus) if gpus <= 8 else (gpus // 8, 8)
        jobs.append(Job(uid=i, tenant="t0", gpu_type=0, n_pods=n_pods,
                        gpus_per_pod=per_pod, priority=PRIO_NORMAL,
                        submit_time=float(arrivals[i]),
                        duration=max(300.0, float(
                            rng.exponential(2400.0)))))
    # Low-priority burst: ~2.4x cluster capacity submitted in the first
    # ten minutes, so a deep low-priority backlog forms immediately.
    n_low = round(2.4 * n_gpus / 11.2)
    for k in range(n_low):
        gpus = int(rng.choice([8, 16], p=[.6, .4]))
        jobs.append(Job(uid=50_000 + k, tenant="t0", gpu_type=0,
                        n_pods=gpus // 8, gpus_per_pod=8,
                        kind=JobKind.TRAIN, priority=PRIO_LOW,
                        submit_time=float(rng.uniform(0.0, 600.0)),
                        duration=max(300.0, float(
                            rng.exponential(2400.0)))))
    return jobs


def frontier_metrics(result: SimResult) -> Dict[str, float]:
    rep = result.metrics.report()
    return {"gar": float(rep["median_gar"]),
            "gfr": float(rep["mean_gfr"]),
            "p90_wait": float(waiting_percentile(result.jobs, 90.0)),
            "p99_wait": float(waiting_percentile(result.jobs, 99.0)),
            "goodput": float(rep["goodput_gpu_seconds"])}


# Per-metric comparison: sense (+1 higher-better / -1 lower-better),
# relative noise tolerance, absolute slack (dominates near zero).
# P99 is the starvation tail the escalator targets; P90 sits in the
# bulk of the distribution and is tracked as a no-regression guard.
METRIC_SENSE = {"gar": +1, "gfr": -1, "p90_wait": -1, "p99_wait": -1,
                "goodput": +1}
METRIC_TOL = {"gar": (0.05, 0.02), "gfr": (0.05, 0.02),
              "p90_wait": (0.10, 120.0), "p99_wait": (0.10, 120.0),
              "goodput": (0.02, 0.0)}


def compare_arm(tuned: Dict[str, float], static: Dict[str, float]
                ) -> Tuple[List[str], List[str]]:
    """(wins, regressions) of the tuned arm against one static arm."""
    wins, regressions = [], []
    for name, sense in METRIC_SENSE.items():
        rel, slack = METRIC_TOL[name]
        margin = abs(static[name]) * rel + slack
        gain = sense * (tuned[name] - static[name])
        if gain > margin:
            wins.append(name)
        elif gain < -margin:
            regressions.append(name)
    return wins, regressions


def tuned_vs_static_gate(seed: int, smoke: bool) -> Dict:
    n_gpus = 512 if smoke else 1024
    jobs = contended_trace(seed, smoke, n_gpus)
    statics = {f"static:{s.name}": s
               for s in (Strategy.E_BINPACK, Strategy.BINPACK,
                         Strategy.E_SPREAD, Strategy.SPREAD)}
    # Priority preemption is off in EVERY arm: the gate isolates what
    # the controllers buy through queue ordering and knob tuning alone,
    # without eviction churn in either arm.
    arms: Dict[str, Dict[str, float]] = {}
    for tag, strategy in statics.items():
        arms[tag] = frontier_metrics(run_sim(jobs, strategy=strategy,
                                             n_gpus=n_gpus,
                                             preempt=False))
    mgr = TuningManager(
        [StarvationEscalator(wait_threshold_s=900.0, boost=30,
                             escalation_period_s=450.0),
         HillClimbController(seed=seed, params=["qsch."],
                             hysteresis=0.02)],
        control_period_s=CONTROL_PERIOD_S)
    tuned_result = run_sim(jobs, strategy=Strategy.E_BINPACK,
                           n_gpus=n_gpus, manager=mgr, preempt=False)
    tuned = frontier_metrics(tuned_result)
    escalator = mgr.controllers[0]
    climber = mgr.controllers[1]
    assert escalator.escalations > 0, \
        "contended trace never triggered the starvation escalator"
    matchups = {}
    for tag, static in arms.items():
        wins, regressions = compare_arm(tuned, static)
        matchups[tag] = {"wins": wins, "regressions": regressions}
        assert wins, (f"tuned arm beat {tag} on no frontier metric: "
                      f"tuned={tuned} static={static}")
        assert not regressions, (
            f"tuned arm regressed {regressions} vs {tag}: "
            f"tuned={tuned} static={static}")
    print(f"--- tuned vs static: beat all {len(arms)} Table-1 profiles "
          f"(P90 wait {tuned['p90_wait']:.0f}s vs "
          f"{arms['static:E_BINPACK']['p90_wait']:.0f}s on the base "
          f"profile; {escalator.escalations} escalations, "
          f"{climber.moves} probes / {climber.reverts} reverts)")
    for tag in arms:
        print(f"    vs {tag}: wins={matchups[tag]['wins']}")
    return {"n_gpus": n_gpus, "tuned": tuned, "static": arms,
            "matchups": matchups,
            "escalations": escalator.escalations,
            "probes": climber.moves, "accepts": climber.accepts,
            "reverts": climber.reverts,
            "control_periods": mgr.periods}


# ----------------------------------------------------------------------
# 3. Warm-start transfer across federation members
# ----------------------------------------------------------------------
def _make_fed(n_nodes: int) -> FederatedCluster:
    return FederatedCluster([
        make_member("dc-a", gpu_pools=((0, n_nodes),), region="west"),
        make_member("dc-b", gpu_pools=((0, n_nodes),), region="west"),
    ])


def _fed_trace(seed: int, smoke: bool, n_gpus: int) -> List[Job]:
    rng = np.random.default_rng(seed)
    window = (4.0 if smoke else 6.0) * 3600.0
    n_jobs = 160 if smoke else 280
    inter = rng.exponential(window / n_jobs, size=n_jobs)
    arrivals = np.cumsum(inter)
    jobs = []
    for i in range(n_jobs):
        gpus = int(rng.choice([4, 8, 16, 32], p=[.3, .35, .2, .15]))
        n_pods, per_pod = (1, gpus) if gpus <= 8 else (gpus // 8, 8)
        jobs.append(Job(uid=i, tenant="t0", gpu_type=0, n_pods=n_pods,
                        gpus_per_pod=per_pod,
                        submit_time=float(arrivals[i]),
                        duration=max(600.0, float(
                            rng.exponential(3000.0)))))
    return jobs


def _normalized_linf(space, a: Dict[str, float], b: Dict[str, float]
                     ) -> float:
    """L-inf distance between two operating points, each coordinate
    normalized by its handle's bound range."""
    worst = 0.0
    for name in a:
        if name not in b or name not in space:
            continue
        p = space.param(name)
        span = p.hi - p.lo
        if span <= 0:
            continue
        worst = max(worst, abs(a[name] - b[name]) / span)
    return worst


CONVERGE_TOL = 0.03     # within 3% of every handle's range


def _periods_to_converge(space, snapshots: Sequence[Dict[str, float]],
                         target: Dict[str, float]) -> int:
    for i, snap in enumerate(snapshots):
        if _normalized_linf(space, target, snap) <= CONVERGE_TOL:
            return i
    return len(snapshots)   # never converged within the run


def warm_start_gate(seed: int, smoke: bool) -> Dict:
    n_nodes = 32
    jobs = _fed_trace(seed, smoke, n_nodes * 8)

    def run_member(member: int, donor=None, climb_seed: int = 0):
        fed = _make_fed(n_nodes)
        fs = FederatedSimulator(fed)
        mgr = TuningManager(
            [HillClimbController(seed=climb_seed, hysteresis=0.0,
                                 epsilon=0.3)],
            control_period_s=CONTROL_PERIOD_S)
        mgr.attach(fs.sims[member], scope=fed.members[member].name,
                   gsch=fs.gsch)
        defaults = mgr.space.snapshot()     # stack defaults
        if donor is not None:
            skipped = mgr.warm_start(donor)
            assert not skipped, f"donor params without handles: {skipped}"
        start = mgr.space.snapshot()        # period-0 operating point
        fs.run(clone_jobs(jobs))
        return mgr, defaults, start

    # Donor: tune member dc-a, export its operating point.
    donor_mgr, defaults, _ = run_member(0, climb_seed=seed)
    donor = donor_mgr.export_profile("dc-a-tuned")
    moved = _normalized_linf(donor_mgr.space, defaults, donor.params)
    assert moved > CONVERGE_TOL, (
        f"donor run moved no parameter beyond tolerance ({moved:.3f}); "
        f"the transfer gate needs a tuned donor")
    payload = donor.to_json()          # exercise the wire format
    donor = type(donor).from_json(payload)

    # Recipients: identical member (dc-b), identical trace — one cold,
    # one warm-started from the donor profile.  A member's trajectory
    # is its period-0 operating point plus the end-of-period snapshots;
    # convergence = first trajectory point within tolerance of the
    # donor's operating point.
    cold, _, cold_start = run_member(1, climb_seed=seed + 1)
    warm, _, warm_start = run_member(1, donor=donor, climb_seed=seed + 1)

    cold_periods = _periods_to_converge(
        cold.space, [cold_start] + cold.period_snapshots, donor.params)
    warm_traj = [warm_start] + warm.period_snapshots
    warm_periods = _periods_to_converge(warm.space, warm_traj,
                                        donor.params)
    # The warm member STARTS at the donor point (period 0); the cold
    # member has to re-walk there, which the guarded climb does not do
    # within the run.
    assert warm_periods < cold_periods, (
        f"warm start did not converge faster: warm={warm_periods} "
        f"cold={cold_periods} periods (of {warm.periods} run)")
    warm_d0 = _normalized_linf(warm.space, donor.params, warm_traj[0]) \
        if warm_traj else float("nan")
    print(f"--- warm start: donor moved {moved:.3f} (range-normalized "
          f"L-inf) over {donor_mgr.periods} periods; warm member at the "
          f"donor point after {warm_periods} periods "
          f"(d0={warm_d0:.3f}) vs cold {cold_periods}+ of "
          f"{cold.periods}")
    return {"donor_moved": moved, "donor_periods": donor_mgr.periods,
            "warm_periods": warm_periods, "cold_periods": cold_periods,
            "run_periods": cold.periods,
            "donor_params_changed": sum(
                1 for n, v in donor.params.items()
                if abs(v - defaults.get(n, v)) > 1e-12)}


# ----------------------------------------------------------------------
# 4. Attached per-cycle overhead at 10k nodes
# ----------------------------------------------------------------------
def _one_cycle_tuned(state: ClusterState, qsch: QSCH, now: float,
                     mgr: Optional[TuningManager], seq: int):
    """Time one bind cycle plus (when attached) the manager's full
    tick path — wait harvest, controller scans, control-period firing —
    then reset the cluster (untimed)."""
    qsch.submit(Job(uid=1, tenant="t0", gpu_type=0, n_pods=GANG_PODS,
                    gpus_per_pod=8, kind=JobKind.TRAIN))
    t0 = time.perf_counter()
    result = qsch.cycle(state, now)
    if mgr is not None:
        mgr._on_tick(Event(t=now, kind=EventKind.TICK, seq=seq))
    dt = time.perf_counter() - t0
    assert len(result.scheduled) == 1, \
        f"bench gang must bind every cycle: {result}"
    bound = result.scheduled[0]
    picks = tuple((p.node, p.gpu_indices)
                  for p in bound.placement.pods)
    state.release(bound.uid)
    qsch.running.clear()
    qsch.quota.refund(bound)
    return dt, picks


def overhead_gate(seed: int, smoke: bool, n_nodes: int = 10_000) -> Dict:
    repeats = 10 if smoke else 30
    state, qsch = _cycle_stack(n_nodes, seed)
    sim = Simulator(state, qsch, SimConfig(tick_interval=30.0))
    # The escalator's queue scan runs every tick; the huge threshold
    # keeps it from mutating priorities so both arms place identically.
    mgr = TuningManager(
        [NoOpController(),
         StarvationEscalator(wait_threshold_s=1e15)],
        control_period_s=CONTROL_PERIOD_S)
    mgr.attach(sim)
    _one_cycle_tuned(state, qsch, 0.0, None, 0)         # warm caches
    _one_cycle_tuned(state, qsch, 0.0, mgr, 0)
    t_det, t_att = [], []
    for i in range(repeats * 2):
        now = 30.0 * (i + 1)
        dt, picks_det = _one_cycle_tuned(state, qsch, now, None, i)
        t_det.append(dt)
        dt, picks_att = _one_cycle_tuned(state, qsch, now, mgr, i)
        t_att.append(dt)
        assert picks_det == picks_att, \
            "attached arm diverged from the detached placements"
    assert not mgr.space.changes, \
        "overhead arms must not mutate parameters"
    det = float(np.median(t_det))
    att = det + float(np.median(np.subtract(t_att, t_det)))
    overhead = att / det - 1.0
    print(f"--- overhead at {n_nodes} nodes ({GANG_PODS}-pod gang): "
          f"detached {det * 1e3:.2f}ms attached {att * 1e3:.2f}ms "
          f"({overhead:+.1%}, budget 5%); {len(mgr.space)} handles, "
          f"escalator scan live")
    assert overhead <= 0.05, (
        f"attached tuning cost {overhead:+.1%} per cycle at "
        f"{n_nodes} nodes, budget is 5%")
    return {"n_nodes": n_nodes, "gang_pods": GANG_PODS,
            "handles": len(mgr.space),
            "detached_cycle_s": det, "attached_cycle_s": att,
            "overhead": overhead}


# ----------------------------------------------------------------------
def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller configs and repeat counts for CI")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the run-wide benchmark seed")
    args = ap.parse_args(argv)
    seed = args.seed if args.seed is not None else bench_seed()
    summary: Dict = {
        "seed": seed,
        "identity": identity_gate(seed, args.smoke),
        "tuned_vs_static": tuned_vs_static_gate(seed, args.smoke),
        "warm_start": warm_start_gate(seed, args.smoke),
        "overhead": overhead_gate(seed, args.smoke),
    }
    write_bench_json("tuning", summary)
    print(f"tuning bench: all gates passed (attached overhead "
          f"{summary['overhead']['overhead']:+.1%})")


if __name__ == "__main__":
    main()
