"""Fig 8: JWTD with E-Binpack vs native (§5.1.3).

Paper: average waiting time decreases across job sizes with E-Binpack —
less fragmentation means gangs find whole nodes sooner."""

import numpy as np

from repro.core import Strategy

from .common import (fragmenting_jobs, loaded_horizon, print_metrics,
                     run_scenario, scaled_training_jobs)


def main() -> dict:
    jobs = fragmenting_jobs(350, seed=9) + [
        j for j in scaled_training_jobs(150, seed=10) if j.n_gpus >= 32]
    for i, j in enumerate(jobs):
        j.uid = i
    spread = run_scenario(jobs, train_strategy=Strategy.SPREAD)
    ebp = run_scenario(jobs, train_strategy=Strategy.E_BINPACK)
    rs = print_metrics("native (spread)", spread)
    rb = print_metrics("E-Binpack", ebp)

    def overall(res):
        w = [j.waiting_time for j in res.jobs if j.waiting_time is not None]
        return float(np.mean(w))

    ws, wb = overall(spread), overall(ebp)
    print(f"overall mean wait: native {ws:.0f}s -> E-Binpack {wb:.0f}s")
    assert wb <= ws * 1.05, "E-Binpack must not worsen mean JWTD"
    return {"wait_native": ws, "wait_ebinpack": wb,
            "jwtd_native": rs["jwtd_mean"], "jwtd_ebinpack": rb["jwtd_mean"]}


if __name__ == "__main__":
    main()
