"""Shared scenario runner for the paper-figure benchmarks.

The paper's §5.1 cluster is 8 000 GPUs; CPU-bound simulation makes us run
a scale model (default 1 024 GPUs = 128 nodes, same 32-node LeafGroups
ratio scaled down, job sizes capped proportionally).  Every benchmark
reports the same metric families the paper plots, and asserts the
paper's *directional* claim.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import (ClusterState, Job, QSCH, QSCHConfig, QueuePolicy,
                        QuotaManager, QuotaMode, RSCH, RSCHConfig,
                        SimConfig, Simulator, SimResult, Strategy,
                        training_trace)
from repro.core.topology import ClusterTopology


def bench_seed(default: int = 0) -> int:
    """The run-wide benchmark seed.

    ``benchmarks/run.py --seed N`` exports ``REPRO_BENCH_SEED`` before
    importing any benchmark module, so every stochastic piece of a
    benchmark (trace generation, failure injection, autoscaler jitter)
    derives from ONE knob and a rerun with the same seed reproduces the
    same numbers bit-for-bit."""
    return int(os.environ.get("REPRO_BENCH_SEED", default))


# Paths written by write_bench_json this process, in order.  The
# orchestrator (benchmarks/run.py --json) snapshots the length before
# each module run to attribute artifacts to the module that wrote them.
RECORDED: List[str] = []


def write_bench_json(name: str, payload: Dict) -> str:
    """Drop a ``BENCH_<name>.json`` summary next to the CWD; CI uploads
    these as workflow artifacts so the perf trajectory is kept per-PR."""
    path = os.path.abspath(f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=float)
    RECORDED.append(path)
    print(f"    [json] {path}")
    return path


def scale_topology(n_gpus: int = 1024, gpus_per_node: int = 8,
                   nodes_per_leaf: int = 8) -> ClusterTopology:
    return ClusterTopology(
        n_nodes=n_gpus // gpus_per_node, gpus_per_node=gpus_per_node,
        nodes_per_leaf=nodes_per_leaf, leaves_per_spine=4,
        spines_per_superspine=4, nodes_per_hbd=nodes_per_leaf,
        nvlink_island=gpus_per_node, numa_split=gpus_per_node // 2)


def scaled_training_jobs(n_jobs: int = 400, *, seed: int = 0,
                         max_gpus: int = 256,
                         arrival_rate_per_hour: float = 400.0,
                         mean_duration_s: float = 3000.0) -> List[Job]:
    """§5.1.1-shaped trace, clipped to the scale cluster (1..max_gpus)."""
    jobs = training_trace(n_jobs, seed=seed,
                          arrival_rate_per_hour=arrival_rate_per_hour,
                          mean_duration_s=mean_duration_s)
    return [j for j in jobs if j.n_gpus <= max_gpus]


def fragmenting_jobs(n_jobs: int = 400, *, seed: int = 0,
                     arrival_rate_per_hour: float = 500.0,
                     mean_duration_s: float = 2500.0) -> List[Job]:
    """Sub-node sizes that fragment nodes unless binpacked (power-of-two
    sizes pack exactly, like the paper's 4/8-GPU request pattern)."""
    rng = np.random.default_rng(seed)
    inter = rng.exponential(3600.0 / arrival_rate_per_hour, size=n_jobs)
    arrivals = np.cumsum(inter)
    jobs = []
    for i in range(n_jobs):
        gpus = int(rng.choice([1, 2, 4, 8, 16],
                              p=[.25, .25, .25, .15, .1]))
        n_pods, per_pod = (1, gpus) if gpus <= 8 else (gpus // 8, 8)
        jobs.append(Job(uid=i, tenant="t0", gpu_type=0, n_pods=n_pods,
                        gpus_per_pod=per_pod,
                        submit_time=float(arrivals[i]),
                        duration=max(120.0, float(
                            rng.exponential(mean_duration_s)))))
    return jobs


def clone_jobs(jobs: Sequence[Job]) -> List[Job]:
    return [Job(uid=j.uid, tenant=j.tenant, gpu_type=j.gpu_type,
                n_pods=j.n_pods, gpus_per_pod=j.gpus_per_pod, kind=j.kind,
                gang=j.gang, priority=j.priority,
                submit_time=j.submit_time, duration=j.duration,
                preemptible=j.preemptible, region=j.region,
                elastic=j.elastic, metadata=j.metadata)
            for j in jobs]


def loaded_horizon(jobs: Sequence[Job], buffer_s: float = 900.0) -> float:
    """Stop metrics at end-of-arrivals: the paper's plots cover the loaded
    window, not the drain tail."""
    return max(j.submit_time for j in jobs) + buffer_s


def run_scenario(jobs: Sequence[Job], *,
                 topo: Optional[ClusterTopology] = None,
                 policy: QueuePolicy = QueuePolicy.BACKFILL,
                 train_strategy: Strategy = Strategy.E_BINPACK,
                 backfill_head_timeout: float = 900.0,
                 quota: Optional[Dict] = None,
                 quota_mode: QuotaMode = QuotaMode.ISOLATED,
                 inference_zone_nodes: int = 0,
                 incremental_snapshots: bool = True,
                 horizon: Optional[float] = None) -> SimResult:
    topo = topo or scale_topology()
    state = ClusterState.create(topo,
                                inference_zone_nodes=inference_zone_nodes)
    qm = QuotaManager(quota or {"t0": {0: 10**6}}, mode=quota_mode)
    rsch = RSCH(topo, RSCHConfig(train_strategy=train_strategy))
    qsch = QSCH(qm, rsch,
                QSCHConfig(policy=policy,
                           backfill_head_timeout=backfill_head_timeout),
                incremental_snapshots=incremental_snapshots)
    sim = Simulator(state, qsch,
                    SimConfig(tick_interval=30.0, sample_interval=300.0,
                              binding_latency=45.0, horizon=horizon))
    return sim.run(clone_jobs(jobs))


def print_metrics(tag: str, result: SimResult) -> Dict[str, float]:
    rep = result.metrics.report()
    print(f"--- {tag}")
    print(f"    median GAR {rep['median_gar']:.3f}   SOR {rep['sor']:.3f}"
          f"   mean GFR {rep['mean_gfr']:.3f}"
          f"   preemptions {result.preemptions}")
    print(f"    waits: quota-rejected {result.admit_rejected}"
          f"   infeasible {result.infeasible}"
          f"   requeues {result.requeues}")
    jw = rep["jwtd_mean"]
    if jw:
        print("    JWTD(s): " + "  ".join(
            f"{k}={v:.0f}" for k, v in jw.items()))
    jt = rep["jtted"]
    if jt:
        print("    JTTED(node,group): " + "  ".join(
            f"{k}=({a:.2f},{b:.2f})" for k, (a, b) in jt.items()))
    return rep
