"""Fig 15: GFR vs cluster scale (§5.2.2).

Paper: under the same churn, smaller clusters show higher GFR — a single
fragmented node weighs 1/N."""

import numpy as np

from repro.core import (ClusterState, QSCH, QSCHConfig, QueuePolicy,
                        QuotaManager, RSCH, SimConfig, Simulator,
                        inference_trace)
from repro.core.topology import small_topology


def run_cluster(n_nodes: int, seed: int = 14) -> float:
    topo = small_topology(n_nodes=n_nodes, gpus_per_node=8,
                          nodes_per_leaf=min(8, n_nodes))
    state = ClusterState.create(topo)
    qm = QuotaManager({"t0": {0: 10**6}, "t1": {0: 10**6},
                       "t2": {0: 10**6}})
    qsch = QSCH(qm, RSCH(topo), QSCHConfig(policy=QueuePolicy.BACKFILL))
    sim = Simulator(state, qsch, SimConfig())
    # identical per-node demand intensity across scales
    jobs = inference_trace(6 * n_nodes, seed=seed,
                           arrival_rate_per_hour=3.0 * n_nodes,
                           mean_duration_s=4 * 3600.0)
    result = sim.run(jobs)
    return float(np.mean([s.gfr for s in result.metrics.samples[2:]]))


def main() -> dict:
    out = {}
    for n in (48, 16, 6):          # i7 > i2 > a10 scale ordering
        out[n] = run_cluster(n)
        print(f"{n:3d} nodes: mean GFR {out[n]:.3f}")
    assert out[6] >= out[48] - 1e-9, \
        "GFR should grow as the cluster shrinks (Fig 15)"
    return {str(k): v for k, v in out.items()}


if __name__ == "__main__":
    main()
