"""Benchmark orchestrator: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` runs everything except the
(hour-scale) dry-run sweeps, which are launched separately via
``python -m repro.launch.dryrun`` and only *read* here by the roofline
table.

``--seed N`` threads one seed through every stochastic benchmark (via
``benchmarks.common.bench_seed``), making runs reproducible
run-to-run; ``--only SUBSTR`` filters modules by name; ``--list``
prints the registered benchmark names and exits (the names ``--only``
matches against)."""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
import traceback

MODULES = [
    ("Fig 2   job distribution", "benchmarks.fig2_job_distribution"),
    ("Fig 3   Backfill GAR/SOR", "benchmarks.fig3_backfill_gar_sor"),
    ("Fig 4   JWTD by policy", "benchmarks.fig4_jwtd_policies"),
    ("Fig 5   Backfill GFR", "benchmarks.fig5_backfill_gfr"),
    ("Fig 6   E-Binpack GFR", "benchmarks.fig6_ebinpack_gfr"),
    ("Fig 7   E-Binpack GAR/SOR", "benchmarks.fig7_ebinpack_gar_sor"),
    ("Fig 8   E-Binpack JWTD", "benchmarks.fig8_ebinpack_jwtd"),
    ("Fig 9   E-Binpack JTTED", "benchmarks.fig9_ebinpack_jtted"),
    ("Fig10-12 tenant quotas", "benchmarks.fig10_quota"),
    ("Fig13-14 inference GAR/GFR", "benchmarks.fig13_inference_gar"),
    ("Fig 15  GFR vs scale", "benchmarks.fig15_gfr_scale"),
    ("§3.4.3  snapshot bench", "benchmarks.snapshot_bench"),
    ("§3.4    sched scale bench", "benchmarks.sched_scale_bench"),
    ("framework plugin bench", "benchmarks.plugin_bench"),
    ("dynamics bench", "benchmarks.dynamics_bench"),
    ("federation bench", "benchmarks.federation_bench"),
    ("serving fabric bench", "benchmarks.serving_bench"),
    ("elastic training bench", "benchmarks.elastic_bench"),
    ("observability bench", "benchmarks.obs_bench"),
    ("kernel  node-score bench", "benchmarks.kernel_bench"),
    ("§Roofline table", "benchmarks.roofline"),
]


def _sanitize(obj):
    """NaN/Inf -> None so the gate summary is strict-JSON parseable."""
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_sanitize(v) for v in obj]
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    return obj


def main(argv=None) -> int:
    import importlib
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0,
                    help="run-wide seed for stochastic benchmarks "
                         "(exported as REPRO_BENCH_SEED)")
    ap.add_argument("--only", default="",
                    help="only run modules whose name contains this")
    ap.add_argument("--list", action="store_true",
                    help="print registered benchmark names and exit")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write a machine-readable per-module gate "
                         "summary (ok/seconds/error/artifacts) to PATH")
    args = ap.parse_args(argv)
    if args.list:
        for title, modname in MODULES:
            print(f"{modname:40s} {title}")
        return 0
    # Exported BEFORE any benchmark module is imported: modules read it
    # through benchmarks.common.bench_seed() at main() time.
    os.environ["REPRO_BENCH_SEED"] = str(args.seed)
    # The orchestrator's flags are its own: a module whose main() parses
    # sys.argv (e.g. dynamics_bench's --smoke) must not choke on
    # --only/--seed, so hide them for the module runs.
    sys.argv = sys.argv[:1]
    failures = []
    selected = [(t, m) for t, m in MODULES if args.only in m]
    if not selected:
        print(f"--only {args.only!r} matches no benchmark module; "
              f"available: {[m for _, m in MODULES]}")
        return 2
    from benchmarks import common
    records = []
    for title, modname in selected:
        print(f"\n================ {title} ({modname})")
        t0 = time.time()
        n_artifacts = len(common.RECORDED)
        rec = {"module": modname, "title": title, "ok": True,
               "seconds": 0.0, "error": None, "artifacts": []}
        try:
            mod = importlib.import_module(modname)
            mod.main()
            print(f"[ok] {title} ({time.time() - t0:.1f}s)")
        except Exception as e:   # noqa: BLE001 — report all, fail at end
            failures.append(title)
            rec["ok"] = False
            rec["error"] = f"{type(e).__name__}: {e}"
            print(f"[FAIL] {title}: {e}")
            traceback.print_exc()
        rec["seconds"] = round(time.time() - t0, 3)
        rec["artifacts"] = list(common.RECORDED[n_artifacts:])
        records.append(rec)
    print("\n================ summary")
    if args.json:
        payload = _sanitize({
            "seed": args.seed,
            "passed": len(selected) - len(failures),
            "failed": len(failures),
            "modules": records,
        })
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"[json] gate summary -> {os.path.abspath(args.json)}")
    if failures:
        print(f"{len(failures)} benchmark(s) failed: {failures}")
        return 1
    print(f"all {len(selected)} benchmarks passed (seed {args.seed})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
