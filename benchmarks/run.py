"""Benchmark orchestrator: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` runs everything except the
(hour-scale) dry-run sweeps, which are launched separately via
``python -m repro.launch.dryrun`` and only *read* here by the roofline
table.

``--seed N`` threads one seed through every stochastic benchmark (via
``benchmarks.common.bench_seed``), making runs reproducible
run-to-run; ``--only SUBSTR`` filters modules by name; ``--list``
prints the registered benchmark names and exits (the names ``--only``
matches against); ``--ci-smoke`` runs exactly the gated subset CI
runs, each module with its smoke flags, so one orchestrator line
replaces a per-bench workflow step and ``--json`` captures the whole
gate matrix in one artifact."""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
import traceback

# (title, module, ci_smoke_argv) — ci_smoke_argv is None for modules
# excluded from the CI gate run (paper-figure sweeps, artifact readers)
# and the module's smoke argv otherwise ([] = run with defaults).
MODULES = [
    ("Fig 2   job distribution", "benchmarks.fig2_job_distribution",
     None),
    ("Fig 3   Backfill GAR/SOR", "benchmarks.fig3_backfill_gar_sor",
     None),
    ("Fig 4   JWTD by policy", "benchmarks.fig4_jwtd_policies", None),
    ("Fig 5   Backfill GFR", "benchmarks.fig5_backfill_gfr", None),
    ("Fig 6   E-Binpack GFR", "benchmarks.fig6_ebinpack_gfr", None),
    ("Fig 7   E-Binpack GAR/SOR", "benchmarks.fig7_ebinpack_gar_sor",
     None),
    ("Fig 8   E-Binpack JWTD", "benchmarks.fig8_ebinpack_jwtd", None),
    ("Fig 9   E-Binpack JTTED", "benchmarks.fig9_ebinpack_jtted", None),
    ("Fig10-12 tenant quotas", "benchmarks.fig10_quota", None),
    ("Fig13-14 inference GAR/GFR", "benchmarks.fig13_inference_gar",
     None),
    ("Fig 15  GFR vs scale", "benchmarks.fig15_gfr_scale", None),
    ("§3.4.3  snapshot bench", "benchmarks.snapshot_bench", []),
    ("§3.4    sched scale bench", "benchmarks.sched_scale_bench",
     ["--smoke", "--check-regression"]),
    ("framework plugin bench", "benchmarks.plugin_bench", []),
    ("dynamics bench", "benchmarks.dynamics_bench", ["--smoke"]),
    ("federation bench", "benchmarks.federation_bench", ["--smoke"]),
    ("serving fabric bench", "benchmarks.serving_bench", ["--smoke"]),
    ("elastic training bench", "benchmarks.elastic_bench", ["--smoke"]),
    ("observability bench", "benchmarks.obs_bench", ["--smoke"]),
    ("self-tuning bench", "benchmarks.tuning_bench", ["--smoke"]),
    ("kernel  node-score bench", "benchmarks.kernel_bench", None),
    ("§Roofline table", "benchmarks.roofline", None),
]


def _sanitize(obj):
    """NaN/Inf -> None so the gate summary is strict-JSON parseable."""
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_sanitize(v) for v in obj]
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    return obj


def main(argv=None) -> int:
    import importlib
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0,
                    help="run-wide seed for stochastic benchmarks "
                         "(exported as REPRO_BENCH_SEED)")
    ap.add_argument("--only", default="",
                    help="only run modules whose name contains this")
    ap.add_argument("--list", action="store_true",
                    help="print registered benchmark names and exit")
    ap.add_argument("--ci-smoke", action="store_true",
                    help="run the CI gate subset, each module with its "
                         "smoke flags")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write a machine-readable per-module gate "
                         "summary (ok/seconds/error/artifacts) to PATH")
    args = ap.parse_args(argv)
    if args.list:
        for title, modname, ci in MODULES:
            mark = "ci" if ci is not None else "  "
            print(f"{modname:40s} [{mark}] {title}")
        return 0
    # Exported BEFORE any benchmark module is imported: modules read it
    # through benchmarks.common.bench_seed() at main() time.
    os.environ["REPRO_BENCH_SEED"] = str(args.seed)
    # The orchestrator's flags are its own: a module whose main() parses
    # sys.argv (e.g. dynamics_bench's --smoke) must not choke on
    # --only/--seed, so hide them for the module runs.
    argv0 = sys.argv[:1]
    sys.argv = argv0
    failures = []
    if args.ci_smoke:
        selected = [(t, m, ci) for t, m, ci in MODULES
                    if ci is not None and args.only in m]
    else:
        selected = [(t, m, None) for t, m, ci in MODULES
                    if args.only in m]
    if not selected:
        print(f"--only {args.only!r} matches no benchmark module; "
              f"available: {[m for _, m, _ in MODULES]}")
        return 2
    from benchmarks import common
    records = []
    for title, modname, ci_argv in selected:
        print(f"\n================ {title} ({modname})")
        t0 = time.time()
        n_artifacts = len(common.RECORDED)
        rec = {"module": modname, "title": title, "ok": True,
               "seconds": 0.0, "error": None, "artifacts": []}
        try:
            # A module that parses sys.argv sees exactly its smoke
            # flags in a --ci-smoke run, nothing otherwise.
            sys.argv = argv0 + (ci_argv or [])
            mod = importlib.import_module(modname)
            mod.main()
            print(f"[ok] {title} ({time.time() - t0:.1f}s)")
        except Exception as e:   # noqa: BLE001 — report all, fail at end
            failures.append(title)
            rec["ok"] = False
            rec["error"] = f"{type(e).__name__}: {e}"
            print(f"[FAIL] {title}: {e}")
            traceback.print_exc()
        rec["seconds"] = round(time.time() - t0, 3)
        rec["artifacts"] = list(common.RECORDED[n_artifacts:])
        records.append(rec)
    print("\n================ summary")
    if args.json:
        payload = _sanitize({
            "seed": args.seed,
            "passed": len(selected) - len(failures),
            "failed": len(failures),
            "modules": records,
        })
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"[json] gate summary -> {os.path.abspath(args.json)}")
    if failures:
        print(f"{len(failures)} benchmark(s) failed: {failures}")
        return 1
    print(f"all {len(selected)} benchmarks passed (seed {args.seed})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
