"""Fig 2: job-count vs GPU-time shares by size (§5.1.1 / §2).

Claims reproduced: >90% of jobs use <8 GPUs yet contribute <10% of
GPU-time; >=256-GPU jobs contribute >50%."""

from repro.core import trace_stats, training_trace


def main() -> dict:
    jobs = training_trace(8000, seed=0)
    stats = trace_stats(jobs)
    rows = sorted(stats.jobs_by_size)
    total_jobs = sum(stats.jobs_by_size.values())
    total_time = sum(stats.gpu_time_by_size.values())
    print("size  #jobs(%)  GPU-time(%)")
    for s in rows:
        print(f"{s:5d}  {100 * stats.jobs_by_size[s] / total_jobs:7.2f}"
              f"  {100 * stats.gpu_time_by_size[s] / total_time:10.2f}")
    small_jobs = stats.job_fraction_below(8)
    small_time = 1 - stats.gpu_time_fraction_at_least(8)
    big_time = stats.gpu_time_fraction_at_least(256)
    print(f"jobs <8 GPUs: {100 * small_jobs:.1f}% of jobs, "
          f"{100 * small_time:.1f}% of GPU-time")
    print(f"jobs >=256 GPUs: {100 * big_time:.1f}% of GPU-time")
    assert small_jobs > 0.75 and small_time < 0.10 and big_time > 0.5
    return {"small_jobs": small_jobs, "small_time": small_time,
            "big_time": big_time}


if __name__ == "__main__":
    main()
