"""Fig 9: JTTED with E-Binpack vs native (§5.1.3) + the beyond-paper
placement-aware roofline.

Paper: estimated training duration improves for every size except the
largest (2048-GPU) jobs — those span many groups either way.  Our
extension converts the deviation ratios into an estimated step time via
the placement-aware roofline (launch/cosched.py)."""

import numpy as np

from repro.core import Strategy
from repro.launch.cosched import estimated_step_time, placement_quality

from .common import print_metrics, run_scenario, scaled_training_jobs, \
    scale_topology


def _mean_step_time(result, topo, terms):
    times = []
    for j in result.jobs:
        if j.placement is None or j.n_gpus < 16:
            continue
        q = placement_quality(j.placement, topo, j.n_gpus)
        times.append(estimated_step_time(terms, q))
    return float(np.mean(times)) if times else 0.0


def main() -> dict:
    topo = scale_topology()
    jobs = [j for j in scaled_training_jobs(450, seed=11)]
    spread = run_scenario(jobs, topo=topo, train_strategy=Strategy.SPREAD)
    ebp = run_scenario(jobs, topo=topo,
                       train_strategy=Strategy.E_BINPACK)
    rs = print_metrics("native (spread)", spread)
    rb = print_metrics("E-Binpack", ebp)

    def mean_group_dev(rep):
        vals = [g for (_, g) in rep["jtted"].values()]
        return float(np.mean(vals)) if vals else 0.0

    gs, gb = mean_group_dev(rs), mean_group_dev(rb)
    print(f"mean NodeNetGroupNum deviation: native {gs:.2f} -> "
          f"E-Binpack {gb:.2f}")
    # Beyond-paper: deviation -> step time via placement-aware roofline.
    # Terms roughly glm4-9b train_4k per-job share (collective-bound).
    terms = {"compute": 1.0, "memory": 1.2, "collective": 1.5}
    ts = _mean_step_time(spread, topo, terms)
    tb = _mean_step_time(ebp, topo, terms)
    print(f"placement-aware roofline step time: native {ts:.3f}s -> "
          f"E-Binpack {tb:.3f}s")
    assert gb <= gs + 1e-9, "E-Binpack must not worsen group deviation"
    assert tb <= ts + 1e-9
    return {"group_dev": (gs, gb), "step_time": (ts, tb)}


if __name__ == "__main__":
    main()
