"""Framework extensibility: contrib Score plugins must move the metrics.

Two beyond-paper plugins ride the extension-point API
(``repro.core.framework``) without touching QSCH/RSCH internals; this
benchmark quantifies their effect and asserts a measurable delta:

* **GfrAwareScore** on an HA-style Spread profile: spreading is
  inherently fragmenting; the multi-objective GFR term must cut mean
  GFR (§4.3) by >=20% while SOR stays within 2% (HA semantics kept).
* **TenantSoftAffinity** on the default E-Binpack profile: each
  tenant's pods must span measurably fewer NodeNetGroups, with JWTD no
  more than 10% worse (soft affinity must not starve anyone).

Usage::

    PYTHONPATH=src python benchmarks/plugin_bench.py
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core import (ClusterState, Job, JobKind, QSCH, QuotaManager,
                        QuotaMode, RSCH, SimConfig, Simulator)
from repro.core.framework import (BackfillPolicy, GfrAwareScore,
                                  PlacementPass, ProfileSet, SpreadScore,
                                  TenantSoftAffinity, default_profiles,
                                  ebinpack_pass, make_profile,
                                  single_pass_plan, spread_pass)
from repro.core.topology import ClusterTopology

TENANTS = ("ads", "search", "ranker")


def topology() -> ClusterTopology:
    return ClusterTopology(n_nodes=64, gpus_per_node=8, nodes_per_leaf=8,
                           leaves_per_spine=4, spines_per_superspine=2,
                           nodes_per_hbd=8, nvlink_island=8, numa_split=4)


def trace(n=260, seed=5, rate_per_hour=300.0, mean_duration_s=1500.0):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(3600.0 / rate_per_hour, size=n))
    jobs = []
    for i in range(n):
        gpus = int(rng.choice([1, 2, 3, 4, 6, 8],
                              p=[.2, .22, .13, .25, .1, .1]))
        jobs.append(Job(uid=i, tenant=TENANTS[i % 3], gpu_type=0,
                        n_pods=1, gpus_per_pod=gpus, kind=JobKind.TRAIN,
                        submit_time=float(arrivals[i]),
                        duration=float(
                            rng.exponential(mean_duration_s) + 300.0)))
    return jobs


def run(profiles: ProfileSet, jobs):
    topo = topology()
    state = ClusterState.create(topo)
    qm = QuotaManager({t: {0: 10**6} for t in TENANTS},
                      mode=QuotaMode.SHARED)
    qsch = QSCH(qm, RSCH(topo, profiles=profiles),
                queue_policy=BackfillPolicy(head_timeout=900.0))
    sim = Simulator(state, qsch, SimConfig(tick_interval=30.0,
                                           sample_interval=120.0))
    result = sim.run([Job(uid=j.uid, tenant=j.tenant, gpu_type=0,
                          n_pods=j.n_pods, gpus_per_pod=j.gpus_per_pod,
                          kind=j.kind, submit_time=j.submit_time,
                          duration=j.duration) for j in jobs])
    return topo, result


def uniform(name, pass_) -> ProfileSet:
    p = make_profile(name, single_pass_plan(pass_))
    return ProfileSet(train=p, inference=p, best_effort=p)


def tenant_group_pairs(topo, result) -> int:
    spans = {}
    for j in result.jobs:
        if j.placement is None:
            continue
        spans.setdefault(j.tenant, set()).update(
            int(topo.leaf_id[p.node]) for p in j.placement.pods)
    return sum(len(g) for g in spans.values())


def mean_jwtd(result) -> float:
    waits = [j.waiting_time for j in result.jobs
             if j.waiting_time is not None]
    return float(np.mean(waits)) if waits else 0.0


def main() -> dict:
    jobs = trace()
    topo = topology()

    print("--- GFR-aware multi-objective scoring (Spread HA base)")
    _, base = run(uniform("ha-spread", spread_pass()), jobs)
    gfr_pass = PlacementPass(
        scorers=(SpreadScore(), GfrAwareScore(weight=0.5, topology=topo)),
        spread=True)
    _, plug = run(uniform("ha-spread-gfr", gfr_pass), jobs)
    g0, g1 = base.metrics.mean_gfr(), plug.metrics.mean_gfr()
    s0, s1 = base.metrics.sor(), plug.metrics.sor()
    cut = (g0 - g1) / max(g0, 1e-9)
    print(f"    mean GFR {g0:.4f} -> {g1:.4f}  ({cut * 100:+.1f}%)"
          f"   SOR {s0:.4f} -> {s1:.4f}")
    assert cut >= 0.20, f"GFR plugin must cut mean GFR >=20%, got {cut:.1%}"
    assert abs(s1 - s0) <= 0.02 * max(s0, 1e-9) + 1e-9, \
        "GFR objective must not change delivered GPU-hours (SOR)"

    print("--- Tenant soft affinity (E-Binpack base)")
    _, ebp = run(default_profiles(), jobs)
    aff_profiles = ProfileSet(
        train=make_profile("train-affinity", single_pass_plan(
            ebinpack_pass(colocate=2.0, extra_scorers=(
                TenantSoftAffinity(topo, weight=0.6, anti_weight=0.3),)))),
        inference=default_profiles().inference,
        best_effort=default_profiles().best_effort)
    _, aff = run(aff_profiles, jobs)
    p0, p1 = tenant_group_pairs(topo, ebp), tenant_group_pairs(topo, aff)
    w0, w1 = mean_jwtd(ebp), mean_jwtd(aff)
    print(f"    tenant-NodeNetGroup pairs {p0} -> {p1}"
          f"   mean JWTD {w0:.1f}s -> {w1:.1f}s")
    assert p1 < p0, "affinity must consolidate tenants into fewer groups"
    assert w1 <= w0 * 1.10 + 1.0, \
        "soft affinity must not degrade JWTD by more than 10%"

    print("[ok] both contrib plugins show measurable metric deltas")
    return {"gfr_cut": cut, "tenant_pairs": (p0, p1),
            "jwtd": (w0, w1)}


if __name__ == "__main__":
    main()
    sys.exit(0)
