"""Elastic-training benchmark: rigid-path parity + shrink/grow payoff.

Two gates, matching the subsystem's acceptance criteria:

1. **Parity** — with an :class:`ElasticManager` attached but no job
   carrying an ``ElasticSpec``, simulation results are byte-identical
   to the plain scheduler across the policy x strategy matrix: same
   placements, same metric report.
2. **Elastic vs rigid** — on a contended trace (steady small rigid
   jobs fragmenting a 512-GPU cluster + large elastic gangs) with
   seeded node failures, elastic scheduling beats the rigid baseline
   on goodput (useful GPU-seconds inside the horizon) AND P90 JWTD,
   while the voluntary reshape cost stays <= 10 % of the useful
   GPU-seconds delivered.

Plan menus come from :func:`repro.core.elastic.spec_from_artifacts`
over synthetic power-law scaling artifacts — the same memoized path a
real dry-run sweep feeds — and the summary reports the plan-cache
hit/miss counters.

Writes ``BENCH_elastic.json`` (uploaded as a CI artifact).
"""

from __future__ import annotations

import argparse
import copy
import math
import os
import sys
from typing import Dict, List, Optional, Sequence

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/elastic_bench.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import (bench_seed, clone_jobs, scale_topology,
                               write_bench_json)  # noqa: E402
from repro.core import (CheckpointModel, ClusterState, DynamicsConfig,
                        ElasticManager, ElasticSpec, Job,
                        NodeFailureInjector, QSCH, QSCHConfig, QueuePolicy,
                        QuotaManager, RSCH, RSCHConfig, SimConfig,
                        Simulator, SimResult, Strategy, scaling_artifacts,
                        spec_from_artifacts, training_trace,
                        waiting_percentile)  # noqa: E402
from repro.core.elastic import plan_cache_stats  # noqa: E402


def run_sim(jobs: Sequence[Job], *, elastic: bool = False,
            policy=QueuePolicy.BACKFILL, strategy=Strategy.E_BINPACK,
            horizon: Optional[float] = None,
            dynamics: Optional[DynamicsConfig] = None,
            n_gpus: int = 512) -> SimResult:
    topo = scale_topology(n_gpus=n_gpus)
    state = ClusterState.create(topo)
    qm = QuotaManager({"t0": {0: 10**6}})
    rsch = RSCH(topo, RSCHConfig(train_strategy=strategy))
    qsch = QSCH(qm, rsch, QSCHConfig(policy=policy),
                elastic=ElasticManager() if elastic else None)
    sim = Simulator(state, qsch,
                    SimConfig(tick_interval=30.0, sample_interval=300.0,
                              binding_latency=45.0, horizon=horizon,
                              dynamics=dynamics))
    return sim.run(clone_jobs(jobs))


def strip_specs(jobs: Sequence[Job]) -> List[Job]:
    """The rigid A/B arm: the same trace with every ElasticSpec
    removed (ideal shapes and durations are already identical)."""
    out = clone_jobs(jobs)
    for j in out:
        j.elastic = None
    return out


def placement_fingerprint(result: SimResult) -> List:
    return [(j.uid, j.start_time, j.end_time,
             tuple((p.node, p.gpu_indices)
                   for p in (j.placement.pods if j.placement else ())))
            for j in result.jobs]


# ----------------------------------------------------------------------
# 1. Parity: manager attached + no specs == plain scheduler
# ----------------------------------------------------------------------
def parity_gate(seed: int, smoke: bool) -> Dict:
    jobs = training_trace(120 if smoke else 240, seed=seed,
                          arrival_rate_per_hour=500,
                          mean_duration_s=2400.0)
    jobs = [j for j in jobs if j.n_gpus <= 128]
    policies = [QueuePolicy.BACKFILL, QueuePolicy.STRICT_FIFO,
                QueuePolicy.BEST_EFFORT_FIFO]
    strategies = [Strategy.E_BINPACK, Strategy.BINPACK]
    checked = 0
    for policy in policies:
        for strategy in strategies:
            base = run_sim(jobs, policy=policy, strategy=strategy)
            managed = run_sim(jobs, policy=policy, strategy=strategy,
                              elastic=True)
            assert placement_fingerprint(base) == placement_fingerprint(
                managed), f"parity broken: {policy} x {strategy}"
            assert base.metrics.report() == managed.metrics.report(), \
                f"metric parity broken: {policy} x {strategy}"
            checked += 1
    print(f"--- parity: {checked} policy x strategy configs "
          f"byte-identical with an idle ElasticManager")
    return {"configs_checked": checked}


# ----------------------------------------------------------------------
# 2. Elastic vs rigid on a contended, failing cluster
# ----------------------------------------------------------------------
def _elastic_spec() -> ElasticSpec:
    """One model family's plan menu (128 GPUs ideal, shrinkable to 64
    and 32) derived from synthetic power-law scaling artifacts through
    the memoized estimation path."""
    return spec_from_artifacts(
        scaling_artifacts("bench-train", "large", [32, 64, 128],
                          alpha=0.85))


def _contended_workload(seed: int, smoke: bool) -> List[Job]:
    """Small rigid jobs keep the cluster fragmented (~50 % load) while
    a burst of 128-GPU gangs — each wanting a quarter of the cluster —
    arrives on top.  Rigid scheduling serializes the gangs; elastic
    ones shrink into whatever is free and grow back as peers finish."""
    rng = np.random.default_rng(seed)
    jobs: List[Job] = []
    n_small = 60 if smoke else 100
    window = (5.0 if smoke else 10.0) * 3600.0
    for i in range(n_small):
        n_gpus = int(rng.choice([8, 16, 32], p=[.45, .35, .2]))
        jobs.append(Job(
            uid=i, tenant="t0", gpu_type=0, n_pods=n_gpus // 8,
            gpus_per_pod=8,
            submit_time=float(rng.uniform(0.0, window)),
            duration=float(rng.uniform(1.0, 2.5)) * 3600.0))
    spec = _elastic_spec()
    ideal = spec.ideal()
    n_big = 8 if smoke else 14
    for k in range(n_big):
        jobs.append(Job(
            uid=10_000 + k, tenant="t0", gpu_type=0,
            n_pods=ideal.n_pods, gpus_per_pod=ideal.gpus_per_pod,
            submit_time=float(rng.uniform(0.0, 0.6 * window)),
            duration=float(rng.uniform(2.0, 3.5)) * 3600.0,
            elastic=spec))
    return jobs


def _censored_jobs(result: SimResult, horizon: float) -> List[Job]:
    """Jobs that never started held the queue until the horizon — count
    that wait instead of silently dropping them (``waiting_percentile``
    only sees started jobs, which would bias P90 toward the arm that
    starved more gangs)."""
    out = []
    for j in result.jobs:
        if j.start_time is None:
            j = copy.copy(j)
            j.start_time = horizon
        out.append(j)
    return out


def elastic_gate(seed: int, smoke: bool) -> Dict:
    jobs = _contended_workload(seed, smoke)
    horizon = (12 if smoke else 22) * 3600.0

    def dynamics():
        return DynamicsConfig(
            plugins=[NodeFailureInjector(mtbf_s=6 * 3600.0,
                                         repair_s=1200.0, shape=1.2)],
            seed=seed,
            recovery=CheckpointModel(interval_s=600.0,
                                     restart_overhead_s=180.0))

    rigid = run_sim(strip_specs(jobs), horizon=horizon,
                    dynamics=dynamics())
    elast = run_sim(jobs, elastic=True, horizon=horizon,
                    dynamics=dynamics())

    good = {"rigid": rigid.metrics.useful_gpu_seconds,
            "elastic": elast.metrics.useful_gpu_seconds}
    p90 = {"rigid": waiting_percentile(
               _censored_jobs(rigid, horizon), 90.0),
           "elastic": waiting_percentile(
               _censored_jobs(elast, horizon), 90.0)}
    # NaN = "no started jobs" (no data) — the scenario must produce
    # waits on both sides before the tail-latency gate means anything.
    assert not any(math.isnan(v) for v in p90.values()), \
        f"no waiting-time data: {p90}"
    overhead_frac = elast.metrics.reshape_overhead_fraction()
    reshapes = elast.metrics.reshapes
    shrunk_starts = sum(
        1 for j in elast.jobs
        if j.elastic is not None and j.active_plan is not None
        and j.active_plan.shape != j.elastic.ideal().shape)

    print(f"--- elastic vs rigid (seed {seed}, "
          f"{elast.failures} failures, {reshapes} grow reshapes, "
          f"{shrunk_starts} jobs finished shrunk)")
    print(f"    goodput GPU-h : rigid {good['rigid']/3600:.0f}  "
          f"elastic {good['elastic']/3600:.0f}  "
          f"({good['elastic']/good['rigid']-1:+.1%})")
    print(f"    P90 JWTD (s)  : rigid {p90['rigid']:.0f}  "
          f"elastic {p90['elastic']:.0f}")
    print(f"    reshape cost  : {overhead_frac:.2%} of useful "
          f"GPU-seconds (budget 10%)")
    assert good["elastic"] > good["rigid"], \
        f"elastic goodput {good['elastic']:.0f} <= rigid {good['rigid']:.0f}"
    assert p90["elastic"] < p90["rigid"], \
        f"elastic P90 JWTD {p90['elastic']:.0f} >= rigid {p90['rigid']:.0f}"
    assert overhead_frac <= 0.10, \
        f"reshape overhead {overhead_frac:.2%} blew the 10% budget"
    return {"goodput_gpu_s": good, "jwtd_p90_s": p90,
            "goodput_gain": good["elastic"] / good["rigid"] - 1.0,
            "reshape_overhead_fraction": overhead_frac,
            "reshapes": reshapes, "shrunk_finishers": shrunk_starts,
            "failures": {"rigid": rigid.failures,
                         "elastic": elast.failures}}


# ----------------------------------------------------------------------
def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller configs for CI (single seed)")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the run-wide benchmark seed")
    args = ap.parse_args(argv)
    seed = args.seed if args.seed is not None else bench_seed()
    seeds = [seed] if args.smoke else [seed, seed + 1, seed + 2]
    summary: Dict = {
        "seed": seed,
        "parity": parity_gate(seed, args.smoke),
        "elastic_vs_rigid": {
            str(s): elastic_gate(s, args.smoke) for s in seeds},
        # Satellite: plan-derivation memo counters — every workload
        # build after the first hits the cache.
        "plan_cache": plan_cache_stats(),
    }
    write_bench_json("elastic", summary)
    print(f"elastic bench: all gates passed "
          f"(plan cache {summary['plan_cache']['hits']} hits / "
          f"{summary['plan_cache']['misses']} misses)")


if __name__ == "__main__":
    main()
