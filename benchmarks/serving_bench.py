"""Serving-fabric benchmark: routing policies, per-slot prefill, demand.

Three gates, each asserting one acceptance criterion of the serving
tier (see docs/serving.md):

1. **Routing** — on the diurnal+bursty mixed-class request trace, the
   ECCOS-style :class:`CapabilityCostRouter` achieves LOWER total cost
   at EQUAL-OR-BETTER SLO attainment than both load-only baselines
   (round-robin, least-loaded), for every seed in the matrix.
2. **Per-slot prefill** — the continuous-batching engine prefills each
   admitted request exactly once (prefill calls == admits, prefill
   tokens == sum of prompt lengths) and its outputs are independent of
   batch co-residents (staggered run == solo B=1 references); the
   legacy whole-batch shim re-prefills residents (strictly more
   prefill tokens for the same request set).
3. **Demand export** — the pool's observed request load round-trips
   into a TidalService whose replica target tracks the trace's peak
   vs trough.

Writes ``BENCH_serving.json`` (uploaded as a CI artifact).
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, List

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/serving_bench.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import bench_seed, write_bench_json  # noqa: E402
from repro.core.workload import request_trace  # noqa: E402
from repro.serve import (CapabilityCostRouter, LeastLoadedRouter,  # noqa: E402
                         ReplicaPool, ReplicaSpec, RoundRobinRouter,
                         demand_service)

PERIOD_S = 1800.0               # one compressed diurnal cycle


def fleet() -> List[ReplicaSpec]:
    """Three heterogeneous tiers, two replicas each.  Token rates are
    equalised across tiers (the large tier is provisioned with more
    accelerators to hold the same speed — which is exactly why its
    $/token is higher); capability and cost scale with size."""
    def mk(name: str, cap: float, cost: float) -> ReplicaSpec:
        return ReplicaSpec(name, capability=cap, cost_per_1k_tokens=cost,
                           prefill_tokens_per_s=6000.0,
                           decode_tokens_per_s=60.0, slots=4)
    return [mk("small-0", 0.40, 0.5), mk("small-1", 0.40, 0.5),
            mk("medium-0", 0.60, 2.0), mk("medium-1", 0.60, 2.0),
            mk("large-0", 0.85, 8.0), mk("large-1", 0.85, 8.0)]


def make_trace(seed: int, n_requests: int):
    return request_trace(n_requests, seed=seed, period_s=PERIOD_S,
                         base_rps=1.0, peak_rps=5.0,
                         burst_rate_per_hour=4.0, burst_duration_s=90.0,
                         burst_multiplier=4.0)


# ----------------------------------------------------------------------
# 1. Routing: capability/cost beats round-robin AND least-loaded
# ----------------------------------------------------------------------
def routing_gate(seed: int, smoke: bool) -> Dict:
    n_requests = 1500 if smoke else 3000
    seeds = [seed] if smoke else [seed, seed + 1, seed + 2]
    policies = {"round_robin": RoundRobinRouter,
                "least_loaded": LeastLoadedRouter,
                "capability_cost": CapabilityCostRouter}
    per_seed: Dict[int, Dict[str, Dict[str, float]]] = {}
    for s in seeds:
        trace = make_trace(s, n_requests)
        rows: Dict[str, Dict[str, float]] = {}
        for name, cls in policies.items():
            pool = ReplicaPool(fleet(), cls())
            rows[name] = pool.route_trace(trace).report()
        per_seed[s] = rows
        cc, rr, ll = (rows["capability_cost"], rows["round_robin"],
                      rows["least_loaded"])
        print(f"--- routing seed {s}: cost "
              f"capcost {cc['total_cost']:.0f} vs "
              f"rr {rr['total_cost']:.0f} / ll {ll['total_cost']:.0f}; "
              f"SLO attainment {cc['slo_attainment']:.3f} vs "
              f"{rr['slo_attainment']:.3f} / {ll['slo_attainment']:.3f} "
              f"({cc['rejected']:.0f} rejected)")
        assert cc["total_cost"] < rr["total_cost"], \
            f"seed {s}: capcost not cheaper than round-robin"
        assert cc["total_cost"] < ll["total_cost"], \
            f"seed {s}: capcost not cheaper than least-loaded"
        assert cc["slo_attainment"] >= rr["slo_attainment"], \
            f"seed {s}: capcost SLO attainment below round-robin"
        assert cc["slo_attainment"] >= ll["slo_attainment"], \
            f"seed {s}: capcost SLO attainment below least-loaded"
    return {str(s): per_seed[s] for s in seeds}


# ----------------------------------------------------------------------
# 2. Per-slot prefill: no resident re-prefill, outputs request-independent
# ----------------------------------------------------------------------
def prefill_gate(seed: int, smoke: bool) -> Dict:
    import jax
    from repro.configs import get_arch
    from repro.models import Model
    from repro.serve import Request, ServeEngine

    cfg = get_arch("glm4-9b", smoke=True)
    params = Model(cfg).init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    lens = [6, 9, 4, 7, 5, 8]
    budgets = [3, 6, 4, 8, 5, 4]    # staggered finishes: slots turn over
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in lens]

    def requests():
        return [Request(uid=i, prompt=p, max_new_tokens=budgets[i])
                for i, p in enumerate(prompts)]

    # Solo references: each request alone in a B=1 engine.
    solo: Dict[int, List[int]] = {}
    for req in requests():
        eng = ServeEngine(cfg, params, batch_size=1, max_seq=64)
        eng.submit(req)
        [r] = eng.run_until_drained()
        solo[r.uid] = list(r.generated)

    per_slot = ServeEngine(cfg, params, batch_size=2, max_seq=64)
    for req in requests():
        per_slot.submit(req)
    fin = per_slot.run_until_drained()
    assert len(fin) == len(prompts)
    assert per_slot.prefill_calls == len(prompts), \
        "per-slot admit must prefill each request exactly once"
    assert per_slot.prefill_tokens == sum(lens), \
        "per-slot admit must never re-prefill resident tokens"
    mismatched = [r.uid for r in fin if list(r.generated) != solo[r.uid]]
    assert not mismatched, \
        f"per-slot outputs depend on batch co-residents: {mismatched}"

    legacy = ServeEngine(cfg, params, batch_size=2, max_seq=64,
                         per_slot_prefill=False)
    for req in requests():
        legacy.submit(req)
    legacy.run_until_drained()
    assert legacy.prefill_tokens > per_slot.prefill_tokens, \
        "legacy shim should re-prefill residents (more prefill tokens)"

    print(f"--- per-slot prefill: {per_slot.prefill_calls} prefills / "
          f"{per_slot.prefill_tokens} tokens for {len(prompts)} requests "
          f"(legacy shim: {legacy.prefill_calls} prefills / "
          f"{legacy.prefill_tokens} tokens); outputs == solo references")
    return {"requests": len(prompts),
            "per_slot": {"prefill_calls": per_slot.prefill_calls,
                         "prefill_tokens": per_slot.prefill_tokens},
            "legacy": {"prefill_calls": legacy.prefill_calls,
                       "prefill_tokens": legacy.prefill_tokens}}


# ----------------------------------------------------------------------
# 3. Demand export: observed load -> TidalService replica targets
# ----------------------------------------------------------------------
def demand_gate(seed: int, smoke: bool) -> Dict:
    trace = make_trace(seed, 1500 if smoke else 3000)
    pool = ReplicaPool(fleet(), CapabilityCostRouter(),
                       demand_bucket_s=60.0)
    pool.route_trace(trace)
    svc = demand_service(pool, min_replicas=1, max_replicas=16)

    span = trace[-1].arrival_s
    ts = np.arange(0.0, span, 60.0)
    rates = [pool.observed_rps(float(t)) for t in ts]
    t_peak = float(ts[int(np.argmax(rates))])
    t_trough = float(ts[int(np.argmin(rates))])
    peak = svc.target_replicas(t_peak)
    trough = svc.target_replicas(t_trough)
    print(f"--- demand export: observed {min(rates):.2f}..{max(rates):.2f}"
          f" rps -> replica target {trough} (trough) .. {peak} (peak)")
    assert peak > trough, \
        "replica target must track the observed demand swing"
    assert 1 <= trough and peak <= 16, "targets must respect min/max"
    return {"target_peak": peak, "target_trough": trough,
            "rps_max": max(rates), "mean_service_s": pool.mean_service_s()}


# ----------------------------------------------------------------------
def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller configs for CI")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the run-wide benchmark seed")
    args = ap.parse_args(argv)
    seed = args.seed if args.seed is not None else bench_seed()
    summary = {
        "seed": seed,
        "routing": routing_gate(seed, args.smoke),
        "per_slot_prefill": prefill_gate(seed, args.smoke),
        "demand_export": demand_gate(seed, args.smoke),
    }
    write_bench_json("serving", summary)
    print("serving bench: all gates passed")


if __name__ == "__main__":
    main()
