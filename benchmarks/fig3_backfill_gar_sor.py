"""Fig 3: GAR and SOR — Backfill vs Strict FIFO (§5.1.2).

Paper: Backfill lifts SOR by ~3.6% median and GAR moderately, because
small jobs run on resources the blocked head cannot use."""

from repro.core import QueuePolicy

from .common import (loaded_horizon, print_metrics, run_scenario,
                     scaled_training_jobs)


def main() -> dict:
    jobs = scaled_training_jobs(600, seed=3, arrival_rate_per_hour=900.0,
                                mean_duration_s=3600.0)
    h = loaded_horizon(jobs)
    strict = run_scenario(jobs, policy=QueuePolicy.STRICT_FIFO, horizon=h)
    backfill = run_scenario(jobs, policy=QueuePolicy.BACKFILL, horizon=h)
    rs = print_metrics("Strict FIFO", strict)
    rb = print_metrics("Backfill", backfill)
    dsor = rb["sor"] - rs["sor"]
    dgar = rb["median_gar"] - rs["median_gar"]
    print(f"Backfill deltas: SOR {dsor:+.3f}  median GAR {dgar:+.3f}")
    assert rb["sor"] > rs["sor"], "Backfill must lift SOR (Fig 3)"
    assert rb["median_gar"] >= rs["median_gar"] - 0.02, \
        "GAR must stay high under Backfill"
    return {"sor_strict": rs["sor"], "sor_backfill": rb["sor"],
            "gar_strict": rs["median_gar"],
            "gar_backfill": rb["median_gar"]}


if __name__ == "__main__":
    main()
