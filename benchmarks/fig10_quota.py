"""Figs 10-12: multi-tenant quotas on a heterogeneous inference cluster
(§5.2.1): per-tenant per-GPU-model quotas, utilization, shared pools."""

import numpy as np

from repro.core import (ClusterState, QSCH, QSCHConfig, QueuePolicy,
                        QuotaManager, QuotaMode, RSCH, SimConfig,
                        Simulator, inference_trace)
from repro.core.topology import ClusterTopology


def main() -> dict:
    # Heterogeneous: 32 Type-L nodes + 32 Type-A nodes.
    topo = ClusterTopology(n_nodes=64, gpus_per_node=8, nodes_per_leaf=8,
                           leaves_per_spine=4, spines_per_superspine=2,
                           nodes_per_hbd=8)
    gpu_type = np.array([0] * 32 + [1] * 32, dtype=np.int32)
    state = ClusterState.create(topo, gpu_type=gpu_type)
    quota = {"t0": {0: 96, 1: 64}, "t1": {0: 96, 1: 64},
             "t2": {0: 64, 1: 128}}
    qm = QuotaManager(quota, mode=QuotaMode.SHARED)
    qsch = QSCH(qm, RSCH(topo), QSCHConfig(policy=QueuePolicy.BACKFILL))
    sim = Simulator(state, qsch, SimConfig())
    jobs = inference_trace(250, seed=12, gpu_types=(0, 1),
                           tenants=("t0", "t1", "t2"),
                           arrival_rate_per_hour=120.0)
    horizon = max(j.submit_time for j in jobs)
    result = sim.run(jobs)
    print("tenant  type  quota  peak-used")
    peak = {}
    for tenant in quota:
        for t in (0, 1):
            used = qm.tenant_used(tenant, t)
            print(f"{tenant:6s}  {t:4d}  {quota[tenant][t]:5d}  "
                  f"{used:9d} (residual)")
    rep = result.metrics.report()
    print(f"median GAR {rep['median_gar']:.3f}  mean GFR "
          f"{rep['mean_gfr']:.3f}")
    # quota accounting is exact: residual equals running jobs
    for tenant in quota:
        for t in (0, 1):
            running = sum(j.n_gpus for j in qsch.running.values()
                          if j.tenant == tenant and j.gpu_type == t)
            assert qm.tenant_used(tenant, t) == running
    return {"gar": rep["median_gar"], "gfr": rep["mean_gfr"]}


if __name__ == "__main__":
    main()
