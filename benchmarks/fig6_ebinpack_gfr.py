"""Fig 6: GFR with E-Binpack enabled vs disabled (§5.1.3).

Paper: E-Binpack drops GFR from ~8.5% to <1%.  The baseline is a
spread-flavoured native scheduler (Kubernetes LeastAllocated) that
scatters sub-node jobs across nodes."""

from repro.core import Strategy

from .common import (fragmenting_jobs, loaded_horizon, print_metrics,
                     run_scenario)


def main() -> dict:
    jobs = fragmenting_jobs(700, seed=6, arrival_rate_per_hour=900.0,
                            mean_duration_s=3600.0)
    h = loaded_horizon(jobs)
    spread = run_scenario(jobs, train_strategy=Strategy.SPREAD, horizon=h)
    ebp = run_scenario(jobs, train_strategy=Strategy.E_BINPACK, horizon=h)
    rs = print_metrics("native (spread)", spread)
    rb = print_metrics("E-Binpack", ebp)
    print(f"GFR: {rs['mean_gfr']:.3f} -> {rb['mean_gfr']:.3f}")
    assert rb["mean_gfr"] < rs["mean_gfr"], "E-Binpack must cut GFR"
    assert rb["mean_gfr"] < 0.5 * rs["mean_gfr"], \
        "E-Binpack should cut GFR by a large factor (paper: 8.5% -> <1%)"
    return {"gfr_native": rs["mean_gfr"], "gfr_ebinpack": rb["mean_gfr"]}


if __name__ == "__main__":
    main()
