"""Figs 13-14: inference-cluster GAR/SOR/GFR (§5.2.2).

Paper (cluster i2): demand near but below capacity -> GAR stable ~93%,
SOR climbing, GFR ~6.5%."""

import numpy as np

from repro.core import (ClusterState, QSCH, QSCHConfig, QueuePolicy,
                        QuotaManager, QuotaMode, RSCH, RSCHConfig,
                        SimConfig, Simulator, inference_trace)
from repro.core.topology import ClusterTopology


def main() -> dict:
    topo = ClusterTopology(n_nodes=24, gpus_per_node=8, nodes_per_leaf=8,
                           leaves_per_spine=3, spines_per_superspine=1,
                           nodes_per_hbd=8)
    state = ClusterState.create(topo, inference_zone_nodes=6)
    qm = QuotaManager({"t0": {0: 10**6}, "t1": {0: 10**6},
                       "t2": {0: 10**6}}, mode=QuotaMode.SHARED)
    qsch = QSCH(qm, RSCH(topo), QSCHConfig(policy=QueuePolicy.BACKFILL))
    sim = Simulator(state, qsch, SimConfig())
    # long-lived services arriving until demand ~ capacity
    jobs = inference_trace(160, seed=13, arrival_rate_per_hour=40.0,
                           mean_duration_s=30 * 3600.0)
    horizon = float(np.quantile([j.submit_time for j in jobs], 0.9))
    sim.config.horizon = horizon
    result = sim.run(jobs)
    samples = result.metrics.samples
    tail = samples[len(samples) // 2:]
    gar_tail = float(np.mean([s.gar for s in tail]))
    gfr_tail = float(np.mean([s.gfr for s in tail]))
    print(f"steady-state GAR {gar_tail:.3f} (paper ~0.93)  "
          f"GFR {gfr_tail:.3f} (paper ~0.065)  SOR {result.metrics.sor():.3f}")
    assert gar_tail > 0.7, "inference cluster should run near capacity"
    return {"gar": gar_tail, "gfr": gfr_tail, "sor": result.metrics.sor()}


if __name__ == "__main__":
    main()
