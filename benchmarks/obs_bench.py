"""Observability benchmark: zero-cost detachment, bounded attach cost.

Three gates, matching the telemetry subsystem's acceptance criteria:

1. **Byte-identity** — attaching a full :class:`repro.obs.Telemetry`
   (registry + tracing + audit) must not perturb the simulation: across
   a policy x strategy matrix, placements, metric reports and the raw
   sample series are identical to the untelemetered run.  Detached,
   every ``obs`` hook is a single ``is None`` branch.
2. **Attached overhead** — with telemetry fully attached, the per-cycle
   scheduling cost on a fragmented 10k-node cluster stays within **5%**
   of the detached cycle.  Both arms are timed interleaved and compared
   by the median of paired per-iteration deltas, so machine-load drift
   and GC outliers cannot fake or mask an overhead.
3. **Trace completeness** — on a seeded elastic run with node failures,
   the emitted Chrome-trace has a span/instant for every lifecycle bus
   event: one ``job-<uid>`` B per SUBMIT, an E at every authoritative
   END, a ``NODE_FAIL`` instant per failure event and a ``reshape``
   instant per voluntary reshape, with every B/E lane balanced.

Writes ``BENCH_obs.json`` plus a sample Perfetto-loadable trace
``BENCH_obs_trace.json`` (both uploaded as CI artifacts).
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/obs_bench.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import (bench_seed, clone_jobs, scale_topology,
                               write_bench_json)  # noqa: E402
from repro.core import (CheckpointModel, ClusterState, DynamicsConfig,
                        ElasticManager, Job, JobKind, JobState,
                        NodeFailureInjector, QSCH, QSCHConfig,
                        QueuePolicy, QuotaManager, RSCH, RSCHConfig,
                        SimConfig, Simulator, SimResult, Strategy,
                        scaling_artifacts, spec_from_artifacts,
                        training_trace)  # noqa: E402
from repro.core.topology import ClusterTopology  # noqa: E402
from repro.obs import PID_JOBS, Telemetry  # noqa: E402


def run_sim(jobs: Sequence[Job], *, policy=QueuePolicy.BACKFILL,
            strategy=Strategy.E_BINPACK, telemetry: Optional[Telemetry]
            = None, horizon: Optional[float] = None,
            dynamics: Optional[DynamicsConfig] = None,
            elastic: bool = False, n_gpus: int = 512) -> SimResult:
    topo = scale_topology(n_gpus=n_gpus)
    state = ClusterState.create(topo)
    qm = QuotaManager({"t0": {0: 10**6}})
    rsch = RSCH(topo, RSCHConfig(train_strategy=strategy))
    qsch = QSCH(qm, rsch, QSCHConfig(policy=policy),
                elastic=ElasticManager() if elastic else None)
    sim = Simulator(state, qsch,
                    SimConfig(tick_interval=30.0, sample_interval=300.0,
                              binding_latency=45.0, horizon=horizon,
                              dynamics=dynamics))
    if telemetry is not None:
        telemetry.attach(sim)
    return sim.run(clone_jobs(jobs))


def placement_fingerprint(result: SimResult) -> List:
    return [(j.uid, j.start_time, j.end_time,
             tuple((p.node, p.gpu_indices)
                   for p in (j.placement.pods if j.placement else ())))
            for j in result.jobs]


def sample_series(result: SimResult) -> List[Dict]:
    return [dataclasses.asdict(s) for s in result.metrics.samples]


# ----------------------------------------------------------------------
# 1. Byte-identity: attached telemetry must not perturb the simulation
# ----------------------------------------------------------------------
def identity_gate(seed: int, smoke: bool) -> Dict:
    jobs = training_trace(80 if smoke else 160, seed=seed,
                          arrival_rate_per_hour=500,
                          mean_duration_s=2400.0)
    jobs = [j for j in jobs if j.n_gpus <= 128]
    configs = [(QueuePolicy.BACKFILL, Strategy.E_BINPACK),
               (QueuePolicy.STRICT_FIFO, Strategy.BINPACK),
               (QueuePolicy.BEST_EFFORT_FIFO, Strategy.E_BINPACK)]
    if not smoke:
        configs += [(QueuePolicy.BACKFILL, Strategy.BINPACK),
                    (QueuePolicy.STRICT_FIFO, Strategy.E_BINPACK),
                    (QueuePolicy.BEST_EFFORT_FIFO, Strategy.BINPACK)]
    families = 0
    for policy, strategy in configs:
        base = run_sim(jobs, policy=policy, strategy=strategy)
        tel = Telemetry()
        inst = run_sim(jobs, policy=policy, strategy=strategy,
                       telemetry=tel)
        tag = f"{policy.name} x {strategy.name}"
        assert placement_fingerprint(base) == placement_fingerprint(
            inst), f"telemetry perturbed placements: {tag}"
        assert base.metrics.report() == inst.metrics.report(), \
            f"telemetry perturbed the metric report: {tag}"
        assert sample_series(base) == sample_series(inst), \
            f"telemetry perturbed the raw sample series: {tag}"
        families = len(tel.registry.names())
        assert families > 0, "attached run registered no metric families"
        assert tel.audit.bound(), f"no decisions audited: {tag}"
    print(f"--- identity: {len(configs)} policy x strategy configs "
          f"byte-identical with full telemetry attached "
          f"({families} metric families)")
    return {"configs_checked": len(configs),
            "metric_families": families}


# ----------------------------------------------------------------------
# 2. Attached per-cycle overhead at 10k nodes
# ----------------------------------------------------------------------
def _fragmented_state(n_nodes: int, seed: int = 0) -> ClusterState:
    """~60% of nodes partially busy (same shape as sched_scale_bench)."""
    topo = ClusterTopology(
        n_nodes=n_nodes, gpus_per_node=8, nodes_per_leaf=32,
        leaves_per_spine=4, spines_per_superspine=4, nodes_per_hbd=32)
    state = ClusterState.create(topo)
    rng = np.random.default_rng(seed)
    busy_nodes = rng.random(n_nodes) < 0.6
    busy_count = rng.integers(1, 9, size=n_nodes)
    for node in np.nonzero(busy_nodes)[0]:
        state.gpu_busy[node, :busy_count[node]] = True
    return state


GANG_PODS = 64


def _cycle_stack(n_nodes: int, seed: int):
    """Production-default QSCH stack (incremental snapshots): every
    cycle runs the complete snapshot -> admit -> filter -> score ->
    select -> reserve -> bind pipeline for one 64-pod gang (the §3.4
    hot path)."""
    state = _fragmented_state(n_nodes, seed)
    qm = QuotaManager({"t0": {0: 10**9}})
    rsch = RSCH(state.topology,
                RSCHConfig(train_strategy=Strategy.E_BINPACK))
    qsch = QSCH(qm, rsch, QSCHConfig(policy=QueuePolicy.STRICT_FIFO))
    return state, qsch


def _one_cycle(state: ClusterState, qsch: QSCH, now: float):
    """Time one bind cycle, then reset the cluster (untimed) so the
    next iteration schedules against the exact same state."""
    qsch.submit(Job(uid=1, tenant="t0", gpu_type=0, n_pods=GANG_PODS,
                    gpus_per_pod=8, kind=JobKind.TRAIN))
    t0 = time.perf_counter()
    result = qsch.cycle(state, now)
    dt = time.perf_counter() - t0
    assert len(result.scheduled) == 1, \
        f"bench gang must bind every cycle: {result}"
    bound = result.scheduled[0]
    picks = tuple((p.node, p.gpu_indices)
                  for p in bound.placement.pods)
    state.release(bound.uid)
    qsch.running.clear()
    qsch.quota.refund(bound)
    return dt, picks


def overhead_gate(seed: int, smoke: bool, n_nodes: int = 10_000) -> Dict:
    repeats = 10 if smoke else 30
    # ONE stack for both arms, with the obs facade toggled per
    # iteration: the detached and attached cycles then share the exact
    # same state, snapshot caches and memory layout, so the paired
    # delta isolates the telemetry code itself.
    state, qsch = _cycle_stack(n_nodes, seed)
    tel = Telemetry()
    tel.attach_qsch(qsch)
    obs = qsch.obs

    def set_obs(o) -> None:
        qsch.obs = o
        qsch.rsch.obs = o

    set_obs(None)
    _one_cycle(state, qsch, 0.0)                        # warm caches
    set_obs(obs)
    _one_cycle(state, qsch, 0.0)
    t_det, t_att = [], []
    for i in range(repeats * 2):
        now = 30.0 * (i + 1)
        set_obs(None)
        dt, picks_det = _one_cycle(state, qsch, now)
        t_det.append(dt)
        set_obs(obs)
        dt, picks_att = _one_cycle(state, qsch, now)
        t_att.append(dt)
        assert picks_det == picks_att, \
            "attached arm diverged from the detached placements"
    # Median of the PAIRED per-iteration deltas: each delta shares its
    # iteration's ambient machine conditions, and the median discards
    # GC/preemption outliers that a min-of-N across arms amplifies.
    det = float(np.median(t_det))
    att = det + float(np.median(np.subtract(t_att, t_det)))
    overhead = att / det - 1.0
    audited = len(tel.audit.bound())
    print(f"--- overhead at {n_nodes} nodes ({GANG_PODS}-pod gang): "
          f"detached {det * 1e3:.2f}ms attached {att * 1e3:.2f}ms "
          f"({overhead:+.1%}, budget 5%); {audited} binds audited")
    assert audited == repeats * 2 + 1, \
        f"expected one audited decision per attached cycle, got {audited}"
    assert overhead <= 0.05, (
        f"attached telemetry cost {overhead:+.1%} per cycle at "
        f"{n_nodes} nodes, budget is 5%")
    return {"n_nodes": n_nodes, "gang_pods": GANG_PODS,
            "detached_cycle_s": det, "attached_cycle_s": att,
            "overhead": overhead}


# ----------------------------------------------------------------------
# 3. Trace completeness on a failing, reshaping cluster
# ----------------------------------------------------------------------
def _dynamic_workload(seed: int, smoke: bool) -> List[Job]:
    """Rigid fragmenters + elastic 128-GPU gangs on 512 GPUs: under
    failures the gangs shrink/grow, producing reshape bus traffic."""
    rng = np.random.default_rng(seed)
    jobs: List[Job] = []
    n_small = 40 if smoke else 80
    window = (4.0 if smoke else 8.0) * 3600.0
    for i in range(n_small):
        n_gpus = int(rng.choice([8, 16, 32], p=[.45, .35, .2]))
        jobs.append(Job(uid=i, tenant="t0", gpu_type=0,
                        n_pods=n_gpus // 8, gpus_per_pod=8,
                        submit_time=float(rng.uniform(0.0, window)),
                        duration=float(rng.uniform(1.0, 2.5)) * 3600.0))
    spec = spec_from_artifacts(
        scaling_artifacts("obs-train", "large", [32, 64, 128],
                          alpha=0.85))
    ideal = spec.ideal()
    for k in range(6 if smoke else 10):
        jobs.append(Job(uid=10_000 + k, tenant="t0", gpu_type=0,
                        n_pods=ideal.n_pods,
                        gpus_per_pod=ideal.gpus_per_pod,
                        submit_time=float(rng.uniform(0.0, 0.6 * window)),
                        duration=float(rng.uniform(2.0, 3.5)) * 3600.0,
                        elastic=spec))
    return jobs


def trace_gate(seed: int, smoke: bool) -> Dict:
    jobs = _dynamic_workload(seed, smoke)
    horizon = (10 if smoke else 18) * 3600.0
    dynamics = DynamicsConfig(
        plugins=[NodeFailureInjector(mtbf_s=4 * 3600.0, repair_s=1200.0,
                                     shape=1.2)],
        seed=seed,
        recovery=CheckpointModel(interval_s=600.0,
                                 restart_overhead_s=180.0))
    tel = Telemetry()
    result = run_sim(jobs, telemetry=tel, horizon=horizon,
                     dynamics=dynamics, elastic=True)
    events = tel.tracer.to_json()["traceEvents"]

    # Every SUBMIT opened a job span; lanes are balanced after finalize.
    begins = {e["name"] for e in events
              if e["ph"] == "B" and e["pid"] == PID_JOBS}
    submitted = {f"job-{j.uid}" for j in result.jobs}
    assert begins == submitted, (
        f"job spans != submitted jobs: {len(begins)} spans for "
        f"{len(submitted)} SUBMITs")
    lanes: Dict[tuple, int] = {}
    for e in events:
        if e["ph"] == "B":
            lanes[(e["pid"], e["tid"])] = lanes.get(
                (e["pid"], e["tid"]), 0) + 1
        elif e["ph"] == "E":
            lanes[(e["pid"], e["tid"])] = lanes.get(
                (e["pid"], e["tid"]), 0) - 1
    assert all(v == 0 for v in lanes.values()), \
        f"unbalanced B/E lanes: {lanes}"

    # Every authoritative END has an E at exactly the job's end time
    # (close_all-injected Es are tagged and excluded).
    ended = {e["name"]: e["ts"] for e in events
             if e["ph"] == "E" and e["pid"] == PID_JOBS
             and not (e.get("args") or {}).get("closed_at_finalize")}
    completed = [j for j in result.jobs if j.state is JobState.COMPLETED]
    assert len(ended) == len(completed), (
        f"{len(ended)} end spans for {len(completed)} completed jobs")
    for j in completed:
        assert abs(ended[f"job-{j.uid}"] - j.end_time * 1e6) < 1.0, \
            f"job {j.uid} E span not at its END time"

    # Every NODE_FAIL bus event and every voluntary reshape left a mark.
    n_fail_inst = sum(1 for e in events
                      if e["ph"] == "i" and e["name"] == "NODE_FAIL")
    n_fail_bus = tel.event_counts.get("NODE_FAIL", 0)
    assert n_fail_bus > 0, "scenario produced no node failures"
    assert n_fail_inst == n_fail_bus, (
        f"{n_fail_inst} NODE_FAIL instants for {n_fail_bus} bus events")
    reshape_inst = sum(1 for e in events
                       if e["ph"] == "i" and e["name"] == "reshape")
    reshapes = result.metrics.reshapes
    assert reshapes > 0, "scenario produced no reshapes"
    assert reshape_inst == reshapes, (
        f"{reshape_inst} reshape instants for {reshapes} reshapes")

    trace_path = tel.save_trace(os.path.abspath("BENCH_obs_trace.json"))
    print(f"--- trace: {len(events)} events cover {len(submitted)} "
          f"SUBMITs, {len(completed)} ENDs, {n_fail_bus} NODE_FAILs, "
          f"{reshapes} reshapes; lanes balanced")
    print(f"    [trace] {trace_path}")
    return {"trace_events": len(events), "jobs": len(submitted),
            "completed": len(completed), "node_fails": n_fail_bus,
            "reshapes": reshapes, "trace_path": trace_path}


# ----------------------------------------------------------------------
def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller configs and repeat counts for CI")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the run-wide benchmark seed")
    args = ap.parse_args(argv)
    seed = args.seed if args.seed is not None else bench_seed()
    summary: Dict = {
        "seed": seed,
        "identity": identity_gate(seed, args.smoke),
        "overhead": overhead_gate(seed, args.smoke),
        "trace": trace_gate(seed, args.smoke),
    }
    write_bench_json("obs", summary)
    print(f"obs bench: all gates passed (attached overhead "
          f"{summary['overhead']['overhead']:+.1%})")


if __name__ == "__main__":
    main()
