"""§3.4.3: incremental snapshots cut scheduler CPU by >50%.

The paper measured >50% RSCH CPU reduction on a 1 000-node cluster; we
time the snapshot path itself (full deep copy vs dirty-row refresh) over
a realistic churn pattern on 1 000 nodes."""

import time

import numpy as np

from repro.core import (ClusterState, FullSnapshotter,
                        IncrementalSnapshotter, Job, Placement,
                        PodPlacement, snapshots_equal)
from repro.core.topology import ClusterTopology


def churn(state: ClusterState, rng, uid: int, dirty_nodes: int = 12):
    """Touch a handful of nodes, as one scheduling cycle would."""
    for _ in range(dirty_nodes):
        node = int(rng.integers(0, state.n_nodes))
        free = np.nonzero(~state.gpu_busy[node])[0]
        if len(free) >= 2:
            job = Job(uid=uid, tenant="t", gpu_type=0, n_pods=1,
                      gpus_per_pod=2)
            state.allocate(job, Placement(pods=[PodPlacement(
                node=node, gpu_indices=(int(free[0]), int(free[1])))]))
            uid += 1
        elif state.allocations:
            state.release(int(rng.choice(list(state.allocations))))
    return uid


def bench(snapshotter, cycles: int = 300, seed: int = 0) -> float:
    topo = ClusterTopology(n_nodes=1000, gpus_per_node=8,
                           nodes_per_leaf=32, leaves_per_spine=4,
                           spines_per_superspine=4, nodes_per_hbd=32)
    state = ClusterState.create(topo)
    rng = np.random.default_rng(seed)
    uid = 0
    snapshotter.take(state)                    # warm
    # Time ONLY the snapshot path — the churn between cycles is the
    # simulated workload, not the thing §3.4.3 optimizes.
    total = 0.0
    for _ in range(cycles):
        uid = churn(state, rng, uid)
        t0 = time.perf_counter()
        snapshotter.take(state)
        total += time.perf_counter() - t0
    return total


def main() -> dict:
    t_full = bench(FullSnapshotter())
    t_inc = bench(IncrementalSnapshotter())
    cut = 1 - t_inc / t_full
    print(f"full-copy: {t_full:.3f}s   incremental: {t_inc:.3f}s   "
          f"CPU cut: {100 * cut:.1f}% (paper: >50%)")
    # correctness spot check under the same churn
    topo = ClusterTopology(n_nodes=200, gpus_per_node=8, nodes_per_leaf=8,
                           leaves_per_spine=5, spines_per_superspine=5,
                           nodes_per_hbd=8)
    state = ClusterState.create(topo)
    rng = np.random.default_rng(1)
    inc = IncrementalSnapshotter()
    uid = 0
    for _ in range(20):
        uid = churn(state, rng, uid)
        assert snapshots_equal(inc.take(state),
                               FullSnapshotter().take(state))
    assert cut > 0.5, f"incremental must cut snapshot CPU >50%, got {cut}"
    return {"full_s": t_full, "incremental_s": t_inc, "cut": cut}


if __name__ == "__main__":
    main()
