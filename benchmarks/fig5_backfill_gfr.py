"""Fig 5: GFR under Backfill vs Strict FIFO (§5.1.2).

Paper: the training cluster's GFR is already <1% (whole-node jobs), and
Backfill leaves it essentially unchanged."""

from repro.core import QueuePolicy

from .common import print_metrics, run_scenario, scaled_training_jobs


def main() -> dict:
    # Whole-node-ish workload like the paper's training cluster.
    jobs = [j for j in scaled_training_jobs(500, seed=5)
            if j.n_gpus % 8 == 0 or j.n_gpus >= 8]
    strict = run_scenario(jobs, policy=QueuePolicy.STRICT_FIFO)
    backfill = run_scenario(jobs, policy=QueuePolicy.BACKFILL)
    rs = print_metrics("Strict FIFO", strict)
    rb = print_metrics("Backfill", backfill)
    print(f"GFR delta: {rb['mean_gfr'] - rs['mean_gfr']:+.4f}")
    assert rb["mean_gfr"] < 0.02, "whole-node workload keeps GFR ~0"
    assert abs(rb["mean_gfr"] - rs["mean_gfr"]) < 0.02
    return {"gfr_strict": rs["mean_gfr"], "gfr_backfill": rb["mean_gfr"]}


if __name__ == "__main__":
    main()
