"""Cluster-dynamics benchmark: parity, checkpoint-restart, tidal.

Three gates, each asserting one acceptance criterion of the dynamics
subsystem:

1. **Parity** — with dynamics disabled (no injectors, no autoscaler)
   simulation results are byte-identical to a plain run across the
   policy x strategy matrix: same placements, same metric report.
2. **Checkpoint-restart** — under a seeded Weibull node-failure trace,
   checkpoint-restart recovery retains >= 80 % of the no-failure
   goodput (useful GPU-seconds of completed work inside the horizon),
   while the restart-from-scratch baseline retains <= 50 %.
3. **Tidal autoscaling** — scaling inference fleets along the diurnal
   demand curve raises overnight GAR (training backfill on reclaimed
   GPUs, and effective GAR counting only *demanded* inference work)
   versus a static peak-sized fleet, at unchanged demand satisfaction.

Writes ``BENCH_dynamics.json`` (uploaded as a CI artifact).
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, List, Optional, Sequence

import numpy as np

if __package__ in (None, ""):   # `python benchmarks/dynamics_bench.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import (bench_seed, clone_jobs, scale_topology,
                               write_bench_json)  # noqa: E402
from repro.core import (CheckpointModel, ClusterState, DynamicsConfig, Job,
                        JobKind, NodeFailureInjector, QSCH, QSCHConfig,
                        QueuePolicy, QuotaManager, RSCH, RSCHConfig,
                        SimConfig, Simulator, SimResult, Strategy,
                        TidalAutoscaler, TidalService,
                        backfill_training_trace)  # noqa: E402

DAY = 86_400.0
NIGHT_HOURS = (0.0, 6.0)        # demand trough (peak_hour=14 -> 2am low)


def run_sim(jobs: Sequence[Job], *, policy=QueuePolicy.BACKFILL,
            strategy=Strategy.E_BINPACK, horizon: Optional[float] = None,
            dynamics: Optional[DynamicsConfig] = None,
            quota: Optional[Dict] = None, n_gpus: int = 512,
            tick: float = 30.0):
    topo = scale_topology(n_gpus=n_gpus)
    state = ClusterState.create(topo)
    qm = QuotaManager(quota or {"t0": {0: 10**6}})
    rsch = RSCH(topo, RSCHConfig(train_strategy=strategy))
    qsch = QSCH(qm, rsch, QSCHConfig(policy=policy))
    sim = Simulator(state, qsch,
                    SimConfig(tick_interval=tick, sample_interval=300.0,
                              binding_latency=45.0, horizon=horizon,
                              dynamics=dynamics))
    return sim.run(clone_jobs(jobs)), state


def placement_fingerprint(result: SimResult) -> List:
    return [(j.uid, j.start_time, j.end_time,
             tuple((p.node, p.gpu_indices)
                   for p in (j.placement.pods if j.placement else ())))
            for j in result.jobs]


# ----------------------------------------------------------------------
# 1. Parity: empty dynamics == no dynamics, byte-identical
# ----------------------------------------------------------------------
def parity_gate(seed: int, smoke: bool) -> Dict:
    from repro.core import training_trace
    jobs = training_trace(120 if smoke else 240, seed=seed,
                          arrival_rate_per_hour=500,
                          mean_duration_s=2400.0)
    jobs = [j for j in jobs if j.n_gpus <= 128]
    policies = [QueuePolicy.BACKFILL, QueuePolicy.STRICT_FIFO,
                QueuePolicy.BEST_EFFORT_FIFO]
    strategies = [Strategy.E_BINPACK, Strategy.BINPACK]
    checked = 0
    for policy in policies:
        for strategy in strategies:
            base, _ = run_sim(jobs, policy=policy, strategy=strategy)
            dyn, _ = run_sim(jobs, policy=policy, strategy=strategy,
                             dynamics=DynamicsConfig())
            assert placement_fingerprint(base) == placement_fingerprint(
                dyn), f"parity broken: {policy} x {strategy}"
            assert base.metrics.report() == dyn.metrics.report(), \
                f"metric parity broken: {policy} x {strategy}"
            checked += 1
    print(f"--- parity: {checked} policy x strategy configs "
          f"byte-identical with empty DynamicsConfig")
    return {"configs_checked": checked}


# ----------------------------------------------------------------------
# 2. Checkpoint-restart vs scratch vs no-failure goodput
# ----------------------------------------------------------------------
def _failure_workload(seed: int, smoke: bool) -> List[Job]:
    """Long jobs relative to the failure MTBF: the regime where restart
    policy decides whether anything finishes at all."""
    from repro.core.workload import _pods_for
    rng = np.random.default_rng(seed)
    n_jobs = 24 if smoke else 48
    jobs = []
    for i in range(n_jobs):
        n_gpus = int(rng.choice([8, 16, 32, 64], p=[.25, .3, .25, .2]))
        n_pods, per_pod = _pods_for(n_gpus, gpus_per_node=8)
        jobs.append(Job(
            uid=i, tenant="t0", gpu_type=0, n_pods=n_pods,
            gpus_per_pod=per_pod,
            submit_time=float(rng.uniform(0.0, 1800.0)),
            duration=float(rng.uniform(4.0, 6.0)) * 3600.0))
    return jobs


def goodput_gate(seed: int, smoke: bool) -> Dict:
    jobs = _failure_workload(seed, smoke)
    horizon = (18 if smoke else 24) * 3600.0
    mtbf = 6 * 3600.0            # per node -> multi-node gangs hit often

    def injector():
        return NodeFailureInjector(mtbf_s=mtbf, repair_s=1200.0,
                                   shape=1.2)

    base, _ = run_sim(jobs, horizon=horizon)
    ckpt, _ = run_sim(jobs, horizon=horizon, dynamics=DynamicsConfig(
        plugins=[injector()], seed=seed,
        recovery=CheckpointModel(interval_s=600.0,
                                 restart_overhead_s=180.0)))
    scratch, _ = run_sim(jobs, horizon=horizon, dynamics=DynamicsConfig(
        plugins=[injector()], seed=seed,
        recovery=CheckpointModel(interval_s=600.0,
                                 restart_overhead_s=180.0,
                                 mode="scratch")))

    base_good = base.metrics.useful_gpu_seconds
    ratios = {"checkpoint": ckpt.metrics.useful_gpu_seconds / base_good,
              "scratch": scratch.metrics.useful_gpu_seconds / base_good}
    print(f"--- checkpoint-restart (node MTBF {mtbf/3600:.0f}h, "
          f"{ckpt.failures} failures, {ckpt.interrupts} interrupts)")
    print(f"    goodput vs no-failure: checkpoint "
          f"{ratios['checkpoint']:.2f}  scratch {ratios['scratch']:.2f}")
    print(f"    MTTR ckpt {ckpt.metrics.mttr():.0f}s   lost work "
          f"{ckpt.metrics.lost_gpu_seconds/3600:.0f} GPU-h (ckpt) vs "
          f"{scratch.metrics.lost_gpu_seconds/3600:.0f} GPU-h (scratch)")
    assert ratios["checkpoint"] >= 0.80, \
        f"checkpoint-restart goodput {ratios['checkpoint']:.2f} < 0.80"
    assert ratios["scratch"] <= 0.50, \
        f"scratch goodput {ratios['scratch']:.2f} > 0.50"
    assert ratios["checkpoint"] > ratios["scratch"]
    return {"goodput_ratio": ratios,
            "failures": ckpt.failures, "interrupts": ckpt.interrupts,
            "mttr_s": ckpt.metrics.mttr(),
            "lost_gpu_h_ckpt": ckpt.metrics.lost_gpu_seconds / 3600.0,
            "lost_gpu_h_scratch":
                scratch.metrics.lost_gpu_seconds / 3600.0}


# ----------------------------------------------------------------------
# 3. Tidal autoscaling vs static peak fleet
# ----------------------------------------------------------------------
def _night(t: float) -> bool:
    h = (t % DAY) / 3600.0
    return NIGHT_HOURS[0] <= h < NIGHT_HOURS[1]


def _services(n_gpus: int) -> List[TidalService]:
    # Peak inference footprint ~half the cluster (4 services x 16
    # replicas x 4 GPUs = 256 of 512); trough ~6%.
    return [TidalService(name=f"svc{i}", tenant="svc",
                         gpus_per_replica=4,
                         min_replicas=2, max_replicas=16,
                         peak_hour=14.0)
            for i in range(4)]


def tidal_gate(seed: int, smoke: bool) -> Dict:
    n_gpus = 512
    horizon = (2 if smoke else 3) * DAY
    services = _services(n_gpus)
    quota = {"svc": {0: 10**6}, "batch": {0: 10**6}}
    # Deep low-priority backlog: enough queued GPU-hours to soak up
    # whatever the tide hands back, all night, every night.
    train = backfill_training_trace(280 if smoke else 460, seed=seed + 1)

    # Static baseline: every service pinned at its peak size for the
    # whole run (classic peak provisioning — demand always satisfied,
    # GPUs held overnight).
    static_fleet = []
    uid = 9_000_000
    for svc in services:
        for _ in range(svc.max_replicas):
            static_fleet.append(Job(
                uid=uid, tenant=svc.tenant, gpu_type=svc.gpu_type,
                n_pods=1, gpus_per_pod=svc.gpus_per_replica,
                kind=JobKind.INFER,
                gang=False, priority=svc.priority, submit_time=0.0,
                duration=horizon + 3600.0, preemptible=False))
            uid += 1
    static, _ = run_sim(train + static_fleet, horizon=horizon,
                        quota=quota, n_gpus=n_gpus)

    scaler = TidalAutoscaler(services, interval_s=900.0)
    tidal, _ = run_sim(train, horizon=horizon, quota=quota,
                       n_gpus=n_gpus,
                       dynamics=DynamicsConfig(plugins=[scaler],
                                               seed=seed))

    def overnight(result: SimResult) -> Dict[str, float]:
        """Mean overnight GAR split: raw, training share, and effective
        (inference counted only up to the demanded footprint)."""
        night = [s for s in result.metrics.samples if _night(s.t)
                 and s.capacity > 0]
        raw = float(np.mean([s.gar for s in night]))
        train_gar = float(np.mean([s.train_allocated / s.capacity
                                   for s in night]))
        eff = []
        for s in night:
            demanded = sum(
                svc.target_replicas(s.t) * svc.gpus_per_replica
                for svc in services)
            useful = s.train_allocated + min(s.infer_allocated, demanded)
            eff.append(useful / s.capacity)
        return {"raw_gar": raw, "train_gar": train_gar,
                "effective_gar": float(np.mean(eff))}

    static_night = overnight(static)
    tidal_night = overnight(tidal)

    # Demand satisfaction: the autoscaler logs its own; the static
    # peak fleet satisfies by construction once placed.
    sat_tidal = scaler.satisfaction()
    sat_static = 1.0
    print(f"--- tidal autoscaler ({tidal.scale_events} scale decisions, "
          f"+{scaler.replicas_started}/-{scaler.replicas_retired} "
          f"replicas, {tidal.preemptions} morning-ramp preemptions)")
    print(f"    overnight GAR   static: raw {static_night['raw_gar']:.2f}"
          f" train {static_night['train_gar']:.2f}"
          f" effective {static_night['effective_gar']:.2f}")
    print(f"    overnight GAR   tidal : raw {tidal_night['raw_gar']:.2f}"
          f" train {tidal_night['train_gar']:.2f}"
          f" effective {tidal_night['effective_gar']:.2f}")
    print(f"    demand satisfaction: static {sat_static:.3f}  "
          f"tidal {sat_tidal:.3f}")
    assert tidal_night["effective_gar"] > static_night["effective_gar"], \
        "tidal must raise overnight effective GAR"
    assert tidal_night["train_gar"] > static_night["train_gar"], \
        "tidal must raise overnight training backfill"
    assert sat_tidal >= sat_static - 0.05, \
        f"demand satisfaction regressed: {sat_tidal:.3f}"
    assert tidal.preemptions > 0, \
        "morning ramp should exercise the Preempt chain"
    return {"overnight_static": static_night,
            "overnight_tidal": tidal_night,
            "satisfaction": {"static": sat_static, "tidal": sat_tidal},
            "replicas": {"started": scaler.replicas_started,
                         "retired": scaler.replicas_retired},
            "preemptions": tidal.preemptions}


# ----------------------------------------------------------------------
def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller configs for CI")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the run-wide benchmark seed")
    args = ap.parse_args(argv)
    seed = args.seed if args.seed is not None else bench_seed()
    summary = {
        "seed": seed,
        "parity": parity_gate(seed, args.smoke),
        "checkpoint_restart": goodput_gate(seed, args.smoke),
        "tidal": tidal_gate(seed, args.smoke),
    }
    write_bench_json("dynamics", summary)
    print("dynamics bench: all gates passed")


if __name__ == "__main__":
    main()
